//! Checkpointing: save/restore the full training state to a compact binary
//! file, so long runs resume exactly.
//!
//! "Full" means everything the trainer mutates while stepping — not just
//! parameters: worker parameters (n x d), optimizer velocities, step
//! counter, simulated clock, the mixer's gossip clock (one-peer-expo must
//! resume mid-period, not at round 0), Gossip-AGA's adaptive-period state
//! (h / counter / F_init), SlowMo's outer buffers (x_prev_sync, slow
//! momentum u), each worker's 256-bit RNG state (so batch streams
//! continue mid-stream), since v3 the CommPlane's cumulative traffic
//! counters plus any compressed-gossip error-feedback residuals, and —
//! since v4 — the per-node virtual clocks (each node's simulated seconds
//! and barrier-wait account, so a heterogeneous/straggler run resumes on
//! its exact time axis). A v2+ checkpoint restored into a *fresh* process
//! replays bit-identically to an unbroken run (v3 for compressed runs,
//! v4 for heterogeneous time axes).
//!
//! Format v6 (little-endian):
//!   magic "GPGA" | u32 version | u64 step | f64 sim_seconds |
//!   u32 n | u32 d | n * d f32 params | u8 has_velocity |
//!   [n * d f32 velocities] | u64 gossip_clock | u8 has_schedule |
//!   [u64 h | u64 counter | f64 f_init | u8 f_init_ready] |
//!   u8 has_slowmo | [d f32 prev | d f32 u] |
//!   u8 has_rng | [n * 4 u64 worker RNG states] |
//!   u8 has_comm | [u64 scalars_sent | u64 msgs | f64 comm_sim_seconds |
//!                  f64 barrier_wait (v4+) | u64 fallback_rounds (v5+) |
//!                  u64 stale_frames_dropped (v8+)] |
//!   u8 has_ef | [u8 codec (1 = topk, 2 = int8) | f64 topk_frac |
//!                u64 int8_block | n * d f32 error-feedback residuals] |
//!   u8 has_clocks | [n f64 node clocks | n f64 node barrier waits] (v4+) |
//!   u8 has_eventsim | [u64 max_staleness | u32 hist_len | hist u64s |
//!                      u32 n_slots | per slot: u64 version | u8 tag |
//!                      (tag 0: d f32 dense | tag 1: f64 mean | f64 var) |
//!                      u32 n_links | per link: u32 src | u32 dst |
//!                      f64 busy_until | f64 busy_seconds |
//!                      u64 cache_version | u32 cache_slot |
//!                      u32 inflight_count | per msg: f64 deliver_at |
//!                      u64 version | u32 slot] (v6; v5 carried payload
//!                      copies inline on every link instead of a slot
//!                      table) |
//!   u8 has_rounds | [u64 round | u64 drops | u64 renorms | u64 rejoins |
//!                    u32 n_alive | n_alive * u8 alive flags] (v7)
//!
//! The v3 tail carries the CommPlane's cumulative traffic counters (so a
//! resumed run's comm_scalars/comm_msgs columns continue rather than
//! restarting at zero) and the per-node error-feedback residuals of
//! compressed-gossip runs (so compressed resumes are exact too). The v4
//! tail snapshots the [`crate::costmodel::VirtualClocks`] — the `sim_seconds`
//! header field stays the critical path (the barrier max), so pre-v4
//! readers of the same quantity and pre-v4 FILES both keep their meaning.
//!
//! The v5/v6 tail snapshots the event-driven async regime's per-edge
//! in-flight/stale state ([`crate::eventsim::EventSimState`]): every link's
//! newest delivered payload (+ version), its in-flight FIFO with absolute
//! virtual delivery times, the link occupancy accounts, and the staleness
//! histogram — so a mid-flight async run resumes bit-exactly, payloads and
//! all. v6 stores payloads once, in a deduplicated slot table the links
//! reference by index (the population plane's [`crate::params::pool`]
//! made payload storage shared, so writing one copy per link occurrence
//! would undo the dedup on disk — and a slot can now also be a
//! statistical surrogate, not only a dense vector). The comm block gained
//! the overlap fallback tally in v5.
//!
//! The v7 tail snapshots the fault-tolerant round machine
//! ([`super::rounds::RoundState`]): the committed-round counter, the
//! drop/renorm/rejoin tallies, and the per-node membership flags — so a
//! run that dropped a stalled peer resumes with the same renormalized
//! mixing rows instead of silently re-admitting the dead node.
//!
//! v8 appends the overlapped-wire stale-frame tally to the comm block
//! ([`CommStats::stale_frames_dropped`]): frames from aborted or
//! already-drained epochs that a bus/tcp endpoint discarded on receipt.
//! Pre-v8 files load with the tally at 0 (those runs predate the
//! message-passing overlap path, so nothing was ever discarded).
//!
//! v1 files (which end after the velocity block), v2 files (which end
//! after the RNG block), v3 files (which end after the ef block) and v4
//! files (which end after the clock block) still load; the extra state
//! defaults to "unset" so old checkpoints keep
//! their old meaning (for v1, callers must replay the data streams
//! themselves, as before; for pre-v3, traffic counters and residuals
//! restart at zero; for pre-v4, every node resumes at the scalar
//! `sim_seconds` with zeroed wait accounts). v5 files load too: each
//! inline payload copy becomes its own slot, in traversal order (links
//! ascending, cache first, then the in-flight FIFO), so the restored
//! engine state is value-identical — it just doesn't share storage until
//! the next interning opportunity.
//!
//! No serde offline — the writer/reader below is the substrate.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::rounds::RoundState;
use crate::algorithms::AgaState;
use crate::comm::{CommStats, Compression};
use crate::eventsim::{EventSimState, LinkSnapshot, SlotSnapshot};
use crate::params::pool::Payload;
use crate::params::ParamMatrix;

const MAGIC: &[u8; 4] = b"GPGA";
const VERSION: u32 = 8;

/// SlowMo outer-loop state (Wang et al. 2019): the parameters at the last
/// global sync and the slow-momentum buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct SlowMoState {
    pub prev: Vec<f32>,
    pub u: Vec<f32>,
}

/// Per-node virtual-time state (v4): node i's simulated clock and its
/// cumulative barrier-wait account, both in seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct ClockState {
    pub seconds: Vec<f64>,
    pub waited: Vec<f64>,
}

/// A snapshot of trainer state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub sim_seconds: f64,
    /// Worker parameters, n x d.
    pub params: ParamMatrix,
    /// Optimizer velocities, n x d (None when momentum == 0 / pre-step).
    pub velocities: Option<ParamMatrix>,
    /// Gossip rounds executed (the time-varying topology's clock).
    pub gossip_clock: u64,
    /// Adaptive-schedule state (None for fixed schedules / v1 files).
    pub schedule: Option<AgaState>,
    /// SlowMo outer buffers (None for other algorithms / v1 files).
    pub slowmo: Option<SlowMoState>,
    /// Per-worker xoshiro256** states, n entries (empty for v1 files —
    /// those resumes must replay the data streams externally).
    pub rng_states: Vec<[u64; 4]>,
    /// Cumulative CommPlane traffic at snapshot time (None for pre-v3
    /// files — counters restart at zero on such resumes).
    pub comm: Option<CommStats>,
    /// Per-node error-feedback residuals of a compressed-gossip run,
    /// n x d (None when compression is off / pre-v3 files).
    pub ef_residuals: Option<ParamMatrix>,
    /// The codec that produced `ef_residuals` — restoring into a run with
    /// a different codec/parameters must be rejected, not silently mixed.
    pub ef_compression: Option<Compression>,
    /// Per-node virtual clocks + barrier-wait accounts (None for pre-v4
    /// files — every node resumes at `sim_seconds`, waits zeroed).
    pub clocks: Option<ClockState>,
    /// The async regime's per-edge in-flight/stale state (None for pre-v5
    /// files and non-async runs — an async resume then re-seeds its link
    /// caches from the restored rows).
    pub eventsim: Option<EventSimState>,
    /// The fault-tolerant round machine's counters + membership (None for
    /// pre-v7 files and runs without `--round-timeout` — restoring a
    /// degraded membership without a machine is rejected by the trainer).
    pub rounds: Option<RoundState>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let n = self.params.n();
        let d = self.params.d();
        if let Some(v) = &self.velocities {
            anyhow::ensure!(
                v.n() == n && v.d() == d,
                "velocity shape {}x{} mismatches params {}x{}",
                v.n(),
                v.d(),
                n,
                d
            );
        }
        if let Some(sm) = &self.slowmo {
            anyhow::ensure!(
                sm.prev.len() == d && sm.u.len() == d,
                "slowmo buffer length mismatch"
            );
        }
        anyhow::ensure!(
            self.rng_states.is_empty() || self.rng_states.len() == n,
            "rng state count {} mismatches {n} workers",
            self.rng_states.len()
        );
        if let Some(r) = &self.ef_residuals {
            anyhow::ensure!(
                r.n() == n && r.d() == d,
                "residual shape {}x{} mismatches params {}x{}",
                r.n(),
                r.d(),
                n,
                d
            );
        }
        let has_codec =
            matches!(self.ef_compression, Some(c) if c != Compression::None);
        anyhow::ensure!(
            self.ef_residuals.is_some() == has_codec,
            "ef_residuals and ef_compression must identify the same codec state"
        );
        if let Some(cs) = &self.clocks {
            anyhow::ensure!(
                cs.seconds.len() == n && cs.waited.len() == n,
                "clock state has {} clocks / {} waits for {n} nodes",
                cs.seconds.len(),
                cs.waited.len()
            );
        }
        if let Some(es) = &self.eventsim {
            let n_slots = es.slots.len() as u32;
            for (idx, s) in es.slots.iter().enumerate() {
                if let Payload::Dense(v) = &s.payload {
                    anyhow::ensure!(
                        v.len() == d,
                        "eventsim slot {idx} payload is {} scalars, not d = {d}",
                        v.len()
                    );
                }
            }
            for l in &es.links {
                anyhow::ensure!(
                    (l.src as usize) < n && (l.dst as usize) < n,
                    "eventsim link ({}, {}) out of range for {n} nodes",
                    l.src,
                    l.dst
                );
                anyhow::ensure!(
                    l.cache_slot < n_slots
                        && l.inflight.iter().all(|&(_, _, slot)| slot < n_slots),
                    "eventsim link ({}, {}) references a slot outside the {n_slots} slot table",
                    l.src,
                    l.dst
                );
            }
        }
        if let Some(rs) = &self.rounds {
            anyhow::ensure!(
                rs.alive.len() == n,
                "round state carries {} membership flags for {n} nodes",
                rs.alive.len()
            );
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.sim_seconds.to_le_bytes())?;
        f.write_all(&(n as u32).to_le_bytes())?;
        f.write_all(&(d as u32).to_le_bytes())?;
        write_f32s(&mut f, self.params.as_slice())?;
        f.write_all(&[self.velocities.is_some() as u8])?;
        if let Some(v) = &self.velocities {
            write_f32s(&mut f, v.as_slice())?;
        }
        f.write_all(&self.gossip_clock.to_le_bytes())?;
        f.write_all(&[self.schedule.is_some() as u8])?;
        if let Some(st) = &self.schedule {
            f.write_all(&(st.h as u64).to_le_bytes())?;
            f.write_all(&(st.counter as u64).to_le_bytes())?;
            f.write_all(&st.f_init.to_le_bytes())?;
            f.write_all(&[st.f_init_ready as u8])?;
        }
        f.write_all(&[self.slowmo.is_some() as u8])?;
        if let Some(sm) = &self.slowmo {
            write_f32s(&mut f, &sm.prev)?;
            write_f32s(&mut f, &sm.u)?;
        }
        f.write_all(&[!self.rng_states.is_empty() as u8])?;
        for st in &self.rng_states {
            for w in st {
                f.write_all(&w.to_le_bytes())?;
            }
        }
        f.write_all(&[self.comm.is_some() as u8])?;
        if let Some(c) = &self.comm {
            f.write_all(&c.scalars_sent.to_le_bytes())?;
            f.write_all(&c.msgs.to_le_bytes())?;
            f.write_all(&c.sim_seconds.to_le_bytes())?;
            f.write_all(&c.barrier_wait.to_le_bytes())?;
            f.write_all(&c.fallback_rounds.to_le_bytes())?;
            f.write_all(&c.stale_frames_dropped.to_le_bytes())?;
        }
        f.write_all(&[self.ef_residuals.is_some() as u8])?;
        if let Some(r) = &self.ef_residuals {
            let (codec, frac, block) = match self.ef_compression {
                Some(Compression::TopK { frac }) => (1u8, frac, 0u64),
                Some(Compression::Int8 { block }) => (2u8, 0.0, block as u64),
                _ => unreachable!("validated above"),
            };
            f.write_all(&[codec])?;
            f.write_all(&frac.to_le_bytes())?;
            f.write_all(&block.to_le_bytes())?;
            write_f32s(&mut f, r.as_slice())?;
        }
        f.write_all(&[self.clocks.is_some() as u8])?;
        if let Some(cs) = &self.clocks {
            for x in cs.seconds.iter().chain(&cs.waited) {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        f.write_all(&[self.eventsim.is_some() as u8])?;
        if let Some(es) = &self.eventsim {
            f.write_all(&es.max_staleness.to_le_bytes())?;
            f.write_all(&(es.hist.len() as u32).to_le_bytes())?;
            for c in &es.hist {
                f.write_all(&c.to_le_bytes())?;
            }
            f.write_all(&(es.slots.len() as u32).to_le_bytes())?;
            for s in &es.slots {
                f.write_all(&s.version.to_le_bytes())?;
                match &s.payload {
                    Payload::Dense(v) => {
                        f.write_all(&[0u8])?;
                        write_f32s(&mut f, v)?;
                    }
                    Payload::Stat { mean, var } => {
                        f.write_all(&[1u8])?;
                        f.write_all(&mean.to_le_bytes())?;
                        f.write_all(&var.to_le_bytes())?;
                    }
                }
            }
            f.write_all(&(es.links.len() as u32).to_le_bytes())?;
            for l in &es.links {
                f.write_all(&l.src.to_le_bytes())?;
                f.write_all(&l.dst.to_le_bytes())?;
                f.write_all(&l.busy_until.to_le_bytes())?;
                f.write_all(&l.busy_seconds.to_le_bytes())?;
                f.write_all(&l.cache_version.to_le_bytes())?;
                f.write_all(&l.cache_slot.to_le_bytes())?;
                f.write_all(&(l.inflight.len() as u32).to_le_bytes())?;
                for (t, v, slot) in &l.inflight {
                    f.write_all(&t.to_le_bytes())?;
                    f.write_all(&v.to_le_bytes())?;
                    f.write_all(&slot.to_le_bytes())?;
                }
            }
        }
        f.write_all(&[self.rounds.is_some() as u8])?;
        if let Some(rs) = &self.rounds {
            f.write_all(&rs.round.to_le_bytes())?;
            f.write_all(&rs.drops.to_le_bytes())?;
            f.write_all(&rs.renorms.to_le_bytes())?;
            f.write_all(&rs.rejoins.to_le_bytes())?;
            f.write_all(&(rs.alive.len() as u32).to_le_bytes())?;
            for &a in &rs.alive {
                f.write_all(&[a as u8])?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a gossip-pga checkpoint (bad magic)");
        }
        let version = read_u32(&mut f)?;
        if version == 0 || version > VERSION {
            bail!("unsupported checkpoint version {version} (this build reads 1..={VERSION})");
        }
        let step = read_u64(&mut f)?;
        let sim_seconds = read_f64(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let d = read_u32(&mut f)? as usize;
        anyhow::ensure!(n < 1 << 20 && d < 1 << 31, "implausible checkpoint dims {n}x{d}");
        let params = ParamMatrix::from_flat(n, d, read_f32s(&mut f, n * d)?);
        let velocities = if read_u8(&mut f)? == 1 {
            Some(ParamMatrix::from_flat(n, d, read_f32s(&mut f, n * d)?))
        } else {
            None
        };
        // v1 files end here; the stateful extras default to "unset".
        let (gossip_clock, schedule, slowmo, rng_states) = if version >= 2 {
            let clock = read_u64(&mut f)?;
            let schedule = if read_u8(&mut f)? == 1 {
                Some(AgaState {
                    h: read_u64(&mut f)? as usize,
                    counter: read_u64(&mut f)? as usize,
                    f_init: read_f64(&mut f)?,
                    f_init_ready: read_u8(&mut f)? == 1,
                })
            } else {
                None
            };
            let slowmo = if read_u8(&mut f)? == 1 {
                Some(SlowMoState { prev: read_f32s(&mut f, d)?, u: read_f32s(&mut f, d)? })
            } else {
                None
            };
            let rng_states = if read_u8(&mut f)? == 1 {
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut st = [0u64; 4];
                    for w in st.iter_mut() {
                        *w = read_u64(&mut f)?;
                    }
                    states.push(st);
                }
                states
            } else {
                Vec::new()
            };
            (clock, schedule, slowmo, rng_states)
        } else {
            (0, None, None, Vec::new())
        };
        let (comm, ef_residuals, ef_compression) = if version >= 3 {
            let comm = if read_u8(&mut f)? == 1 {
                Some(CommStats {
                    scalars_sent: read_u64(&mut f)?,
                    msgs: read_u64(&mut f)?,
                    sim_seconds: read_f64(&mut f)?,
                    // The barrier-wait breakdown joined the comm block in
                    // v4, the overlap fallback tally in v5; older files
                    // carry the earlier accounting.
                    barrier_wait: if version >= 4 { read_f64(&mut f)? } else { 0.0 },
                    fallback_rounds: if version >= 5 { read_u64(&mut f)? } else { 0 },
                    stale_frames_dropped: if version >= 8 { read_u64(&mut f)? } else { 0 },
                })
            } else {
                None
            };
            let (ef_residuals, ef_compression) = if read_u8(&mut f)? == 1 {
                let codec = read_u8(&mut f)?;
                let frac = read_f64(&mut f)?;
                let block = read_u64(&mut f)? as usize;
                let compression = match codec {
                    1 => Compression::TopK { frac },
                    2 => Compression::Int8 { block },
                    other => bail!("unknown checkpoint codec tag {other}"),
                };
                (
                    Some(ParamMatrix::from_flat(n, d, read_f32s(&mut f, n * d)?)),
                    Some(compression),
                )
            } else {
                (None, None)
            };
            (comm, ef_residuals, ef_compression)
        } else {
            (None, None, None)
        };
        let clocks = if version >= 4 && read_u8(&mut f)? == 1 {
            let mut seconds = Vec::with_capacity(n);
            for _ in 0..n {
                seconds.push(read_f64(&mut f)?);
            }
            let mut waited = Vec::with_capacity(n);
            for _ in 0..n {
                waited.push(read_f64(&mut f)?);
            }
            Some(ClockState { seconds, waited })
        } else {
            None
        };
        let eventsim = if version >= 5 && read_u8(&mut f)? == 1 {
            let max_staleness = read_u64(&mut f)?;
            let hist_len = read_u32(&mut f)? as usize;
            anyhow::ensure!(hist_len < 1 << 20, "implausible staleness histogram length {hist_len}");
            let mut hist = Vec::with_capacity(hist_len);
            for _ in 0..hist_len {
                hist.push(read_u64(&mut f)?);
            }
            let mut slots: Vec<SlotSnapshot> = Vec::new();
            if version >= 6 {
                let n_slots = read_u32(&mut f)? as usize;
                anyhow::ensure!(n_slots < 1 << 24, "implausible slot count {n_slots}");
                slots.reserve(n_slots);
                for idx in 0..n_slots {
                    let slot_version = read_u64(&mut f)?;
                    let payload = match read_u8(&mut f)? {
                        0 => Payload::Dense(read_f32s(&mut f, d)?),
                        1 => Payload::Stat { mean: read_f64(&mut f)?, var: read_f64(&mut f)? },
                        other => bail!("unknown checkpoint payload tag {other} in slot {idx}"),
                    };
                    slots.push(SlotSnapshot { version: slot_version, payload });
                }
            }
            let n_links = read_u32(&mut f)? as usize;
            anyhow::ensure!(n_links <= n * n, "implausible link count {n_links} for {n} nodes");
            let mut links = Vec::with_capacity(n_links);
            for _ in 0..n_links {
                let src = read_u32(&mut f)?;
                let dst = read_u32(&mut f)?;
                let busy_until = read_f64(&mut f)?;
                let busy_seconds = read_f64(&mut f)?;
                let cache_version = read_u64(&mut f)?;
                let cache_slot = if version >= 6 {
                    let slot = read_u32(&mut f)?;
                    anyhow::ensure!(
                        (slot as usize) < slots.len(),
                        "link ({src}, {dst}) cache references slot {slot} outside the table"
                    );
                    slot
                } else {
                    // v5 stored the payload inline; give the copy its own
                    // slot (traversal order: links ascending, cache first).
                    let slot = slots.len() as u32;
                    slots.push(SlotSnapshot {
                        version: cache_version,
                        payload: Payload::Dense(read_f32s(&mut f, d)?),
                    });
                    slot
                };
                let inflight_count = read_u32(&mut f)? as usize;
                anyhow::ensure!(
                    inflight_count < 1 << 20,
                    "implausible in-flight count {inflight_count} on link ({src}, {dst})"
                );
                let mut inflight = Vec::with_capacity(inflight_count);
                for _ in 0..inflight_count {
                    let t = read_f64(&mut f)?;
                    let v = read_u64(&mut f)?;
                    let slot = if version >= 6 {
                        let slot = read_u32(&mut f)?;
                        anyhow::ensure!(
                            (slot as usize) < slots.len(),
                            "link ({src}, {dst}) in-flight payload references slot {slot} \
                             outside the table"
                        );
                        slot
                    } else {
                        let slot = slots.len() as u32;
                        slots.push(SlotSnapshot {
                            version: v,
                            payload: Payload::Dense(read_f32s(&mut f, d)?),
                        });
                        slot
                    };
                    inflight.push((t, v, slot));
                }
                links.push(LinkSnapshot {
                    src,
                    dst,
                    busy_until,
                    busy_seconds,
                    cache_version,
                    cache_slot,
                    inflight,
                });
            }
            Some(EventSimState { max_staleness, hist, slots, links })
        } else {
            None
        };
        let rounds = if version >= 7 && read_u8(&mut f)? == 1 {
            let round = read_u64(&mut f)?;
            let drops = read_u64(&mut f)?;
            let renorms = read_u64(&mut f)?;
            let rejoins = read_u64(&mut f)?;
            let n_alive = read_u32(&mut f)? as usize;
            anyhow::ensure!(
                n_alive == n,
                "round membership covers {n_alive} nodes, checkpoint has {n}"
            );
            let mut alive = Vec::with_capacity(n_alive);
            for _ in 0..n_alive {
                alive.push(match read_u8(&mut f)? {
                    0 => false,
                    1 => true,
                    other => bail!("corrupt membership flag {other} in the round block"),
                });
            }
            Some(RoundState { round, drops, renorms, rejoins, alive })
        } else {
            None
        };
        Ok(Checkpoint {
            step,
            sim_seconds,
            params,
            velocities,
            gossip_clock,
            schedule,
            slowmo,
            rng_states,
            comm,
            ef_residuals,
            ef_compression,
            clocks,
            eventsim,
            rounds,
        })
    }
}

/// Elements staged per I/O chunk: checkpoints can be multi-GB (n x d at
/// BERT scale), so the byte staging buffer stays bounded (~4 MiB) instead
/// of doubling peak memory with a full-payload temporary.
const IO_CHUNK: usize = 1 << 20;

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(IO_CHUNK.min(xs.len()) * 4);
    for chunk in xs.chunks(IO_CHUNK.max(1)) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; IO_CHUNK.min(n.max(1)) * 4];
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(IO_CHUNK);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes)?;
        out.extend(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        remaining -= take;
    }
    Ok(out)
}

fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64<R: Read>(r: &mut R) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gpga_ckpt_{}_{name}.bin", std::process::id()))
    }

    fn random_matrix(n: usize, d: usize, seed: u64, scale: f32) -> ParamMatrix {
        ParamMatrix::random(&mut Rng::new(seed), n, d, scale)
    }

    #[test]
    fn roundtrip_with_velocities() {
        let ck = Checkpoint {
            step: 1234,
            sim_seconds: 56.78,
            params: random_matrix(3, 17, 1, 1.0),
            velocities: Some(random_matrix(3, 17, 2, 0.1)),
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        let path = tmp("vel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_without_velocities() {
        let ck = Checkpoint {
            step: 1,
            sim_seconds: 0.0,
            params: ParamMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]),
            velocities: None,
            gossip_clock: 7,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        let path = tmp("novel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_full_stateful_fields() {
        // The state-loss regression: gossip clock, AGA recursion state and
        // SlowMo outer buffers must all survive the file.
        let d = 9;
        let mut rng = Rng::new(3);
        let ck = Checkpoint {
            step: 77,
            sim_seconds: 12.5,
            params: random_matrix(4, d, 4, 1.0),
            velocities: Some(random_matrix(4, d, 5, 0.2)),
            gossip_clock: 41,
            schedule: Some(AgaState { h: 12, counter: 5, f_init: 0.6931, f_init_ready: true }),
            slowmo: Some(SlowMoState {
                prev: rng.normal_vec(d, 1.0),
                u: rng.normal_vec(d, 0.5),
            }),
            rng_states: (0..4u64).map(|i| Rng::new(i).state()).collect(),
            comm: Some(CommStats {
                scalars_sent: 123_456,
                msgs: 789,
                sim_seconds: 4.2,
                barrier_wait: 0.7,
                fallback_rounds: 3,
                stale_frames_dropped: 12,
            }),
            ef_residuals: Some(random_matrix(4, d, 6, 0.01)),
            ef_compression: Some(Compression::TopK { frac: 0.25 }),
            clocks: Some(ClockState {
                seconds: vec![12.5, 11.0, 12.5, 9.25],
                waited: vec![0.0, 1.5, 0.0, 3.25],
            }),
            eventsim: None,
            rounds: None,
        };
        let path = tmp("stateful");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_v1_files_with_default_extras() {
        // Hand-write the v1 layout: it ends right after the velocity block.
        let path = tmp("v1");
        let params = vec![1.0f32, 2.0, 3.0, 4.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&9u64.to_le_bytes());
        bytes.extend_from_slice(&2.5f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 9);
        assert_eq!(back.params.as_slice(), &params[..]);
        assert_eq!(back.gossip_clock, 0);
        assert!(back.schedule.is_none() && back.slowmo.is_none() && back.velocities.is_none());
        assert!(back.rng_states.is_empty());
        assert!(back.comm.is_none() && back.ef_residuals.is_none());
        assert!(back.ef_compression.is_none());
        assert!(back.clocks.is_none(), "v1 files predate per-node clocks");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_v2_files_which_end_after_the_rng_block() {
        let path = tmp("v2");
        let params = vec![1.0f32, 0.0, 0.0, 1.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&17u64.to_le_bytes());
        bytes.extend_from_slice(&3.5f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        bytes.extend_from_slice(&3u64.to_le_bytes()); // gossip clock
        bytes.push(0); // no schedule
        bytes.push(0); // no slowmo
        bytes.push(1); // rng states, 2 workers x 4 words
        for w in 0..8u64 {
            bytes.extend_from_slice(&(w + 1).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.gossip_clock, 3);
        assert_eq!(back.rng_states.len(), 2);
        assert_eq!(back.rng_states[1], [5, 6, 7, 8]);
        assert!(back.comm.is_none(), "v2 files predate comm totals");
        assert!(back.clocks.is_none(), "v2 files predate per-node clocks");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_v3_files_with_three_field_comm_and_no_clocks() {
        // Hand-write the v3 layout: comm block has no barrier_wait and the
        // file ends after the ef flag — the pre-virtual-time format.
        let path = tmp("v3");
        let params = vec![0.5f32, 1.5, -2.0, 3.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(&40u64.to_le_bytes());
        bytes.extend_from_slice(&7.25f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        bytes.extend_from_slice(&5u64.to_le_bytes()); // gossip clock
        bytes.push(0); // no schedule
        bytes.push(0); // no slowmo
        bytes.push(0); // no rng
        bytes.push(1); // comm present — THREE fields in v3
        bytes.extend_from_slice(&1000u64.to_le_bytes());
        bytes.extend_from_slice(&20u64.to_le_bytes());
        bytes.extend_from_slice(&1.5f64.to_le_bytes());
        bytes.push(0); // no ef residuals; v3 files end here
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 40);
        assert_eq!(back.gossip_clock, 5);
        let comm = back.comm.unwrap();
        assert_eq!((comm.scalars_sent, comm.msgs), (1000, 20));
        assert_eq!(comm.sim_seconds, 1.5);
        assert_eq!(comm.barrier_wait, 0.0, "v3 comm blocks predate barrier waits");
        assert!(back.clocks.is_none(), "v3 files predate per-node clocks");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn clock_state_roundtrips_and_shape_mismatch_rejected() {
        let mut ck = Checkpoint {
            step: 3,
            sim_seconds: 10.0,
            params: ParamMatrix::zeros(3, 2),
            velocities: None,
            gossip_clock: 1,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: Some(ClockState {
                seconds: vec![10.0, 8.0, 6.5],
                waited: vec![0.0, 2.0, 3.5],
            }),
            eventsim: None,
            rounds: None,
        };
        let path = tmp("clocks");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
        // 2 clocks for 3 nodes: refuse to write a partial time axis.
        ck.clocks = Some(ClockState { seconds: vec![1.0, 2.0], waited: vec![0.0, 0.0, 0.0] });
        assert!(ck.save(&tmp("clkmis")).is_err());
    }

    #[test]
    fn eventsim_state_roundtrips_and_validates() {
        // The v6 block: the deduplicated slot table + per-edge cache /
        // mid-flight slot references + link occupancy + staleness
        // histogram survive the file bit-exactly. One slot is a
        // statistical surrogate — the population plane checkpoints too.
        let d = 3;
        let slots = vec![
            SlotSnapshot { version: 9, payload: Payload::Dense(vec![0.5; d]) },
            SlotSnapshot { version: 10, payload: Payload::Dense(vec![1.5; d]) },
            SlotSnapshot { version: 11, payload: Payload::Stat { mean: -2.0, var: 0.25 } },
        ];
        let mk_link = |src: u32, dst: u32| LinkSnapshot {
            src,
            dst,
            busy_until: 7.5,
            busy_seconds: 2.25,
            cache_version: 9,
            cache_slot: 0,
            inflight: vec![(8.0, 10, 1), (9.5, 11, 2)],
        };
        let mut ck = Checkpoint {
            step: 12,
            sim_seconds: 8.0,
            params: ParamMatrix::zeros(2, d),
            velocities: None,
            gossip_clock: 12,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: Some(CommStats {
                scalars_sent: 72,
                msgs: 24,
                sim_seconds: 1.0,
                barrier_wait: 0.5,
                fallback_rounds: 0,
                stale_frames_dropped: 0,
            }),
            ef_residuals: None,
            ef_compression: None,
            clocks: Some(ClockState { seconds: vec![8.0, 6.0], waited: vec![0.0, 1.0] }),
            eventsim: Some(EventSimState {
                max_staleness: 2,
                hist: vec![40, 7, 1],
                slots,
                links: vec![mk_link(0, 1), mk_link(1, 0)],
            }),
            rounds: None,
        };
        let path = tmp("eventsim");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
        // A dense slot of the wrong width is refused at save time...
        let pristine = ck.clone();
        if let Some(es) = ck.eventsim.as_mut() {
            es.slots[0].payload = Payload::Dense(vec![0.0; d + 1]);
        }
        assert!(ck.save(&tmp("evmis")).is_err());
        // ...and so is a link pointing outside the slot table.
        let mut ck = pristine;
        if let Some(es) = ck.eventsim.as_mut() {
            es.links[0].inflight[0].2 = 99;
        }
        assert!(ck.save(&tmp("evslot")).is_err());
    }

    #[test]
    fn loads_v5_files_by_slotting_each_inline_payload_copy() {
        // Hand-write the v5 eventsim tail (payload copies inline on the
        // link): the loader must convert every occurrence to its own slot
        // in traversal order — cache first, then the in-flight FIFO.
        let path = tmp("v5");
        let params = vec![0.0f32, 1.0, 2.0, 3.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(&33u64.to_le_bytes());
        bytes.extend_from_slice(&4.0f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        bytes.extend_from_slice(&12u64.to_le_bytes()); // gossip clock
        bytes.push(0); // no schedule
        bytes.push(0); // no slowmo
        bytes.push(0); // no rng
        bytes.push(0); // no comm
        bytes.push(0); // no ef residuals
        bytes.push(0); // no clocks
        bytes.push(1); // eventsim present — the v5 inline layout
        bytes.extend_from_slice(&2u64.to_le_bytes()); // max_staleness
        bytes.extend_from_slice(&2u32.to_le_bytes()); // hist_len
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one link
        bytes.extend_from_slice(&0u32.to_le_bytes()); // src
        bytes.extend_from_slice(&1u32.to_le_bytes()); // dst
        bytes.extend_from_slice(&1.5f64.to_le_bytes()); // busy_until
        bytes.extend_from_slice(&0.5f64.to_le_bytes()); // busy_seconds
        bytes.extend_from_slice(&3u64.to_le_bytes()); // cache_version
        for x in [0.25f32, -0.25] {
            bytes.extend_from_slice(&x.to_le_bytes()); // inline cache
        }
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one in-flight msg
        bytes.extend_from_slice(&2.0f64.to_le_bytes()); // deliver_at
        bytes.extend_from_slice(&4u64.to_le_bytes()); // version
        for x in [1.0f32, 2.0] {
            bytes.extend_from_slice(&x.to_le_bytes()); // inline payload
        }
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let es = back.eventsim.unwrap();
        assert_eq!(es.max_staleness, 2);
        assert_eq!(es.hist, vec![4, 1]);
        assert_eq!(
            es.slots,
            vec![
                SlotSnapshot { version: 3, payload: Payload::Dense(vec![0.25, -0.25]) },
                SlotSnapshot { version: 4, payload: Payload::Dense(vec![1.0, 2.0]) },
            ]
        );
        assert_eq!(es.links.len(), 1);
        assert_eq!((es.links[0].src, es.links[0].dst), (0, 1));
        assert_eq!(es.links[0].cache_slot, 0);
        assert_eq!(es.links[0].inflight, vec![(2.0, 4, 1)]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_v4_files_which_end_after_the_clock_block() {
        // Hand-write the v4 layout: four-field comm block, clock block,
        // no eventsim tail — the pre-event-plane format.
        let path = tmp("v4");
        let params = vec![1.0f32, -1.0, 2.0, -2.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&21u64.to_le_bytes());
        bytes.extend_from_slice(&5.5f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        bytes.extend_from_slice(&20u64.to_le_bytes()); // gossip clock
        bytes.push(0); // no schedule
        bytes.push(0); // no slowmo
        bytes.push(0); // no rng
        bytes.push(1); // comm present — FOUR fields in v4
        bytes.extend_from_slice(&500u64.to_le_bytes());
        bytes.extend_from_slice(&10u64.to_le_bytes());
        bytes.extend_from_slice(&0.75f64.to_le_bytes());
        bytes.extend_from_slice(&0.25f64.to_le_bytes());
        bytes.push(0); // no ef residuals
        bytes.push(1); // clocks present
        for x in [5.5f64, 4.5, 0.0, 1.0] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        // v4 files end here.
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 21);
        let comm = back.comm.unwrap();
        assert_eq!(comm.barrier_wait, 0.25);
        assert_eq!(comm.fallback_rounds, 0, "v4 comm blocks predate the fallback tally");
        let clocks = back.clocks.unwrap();
        assert_eq!(clocks.seconds, vec![5.5, 4.5]);
        assert_eq!(clocks.waited, vec![0.0, 1.0]);
        assert!(back.eventsim.is_none(), "v4 files predate the event plane");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_residual_shape_mismatch() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(2, 3),
            velocities: None,
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: Some(ParamMatrix::zeros(2, 4)),
            ef_compression: Some(Compression::Int8 { block: 64 }),
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        assert!(ck.save(&tmp("efmis")).is_err());
        // Residuals without a codec identity are rejected too.
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(2, 3),
            velocities: None,
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: Some(ParamMatrix::zeros(2, 3)),
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        assert!(ck.save(&tmp("efnocodec")).is_err());
    }

    #[test]
    fn round_state_roundtrips_and_validates() {
        // The v7 block: counters + membership flags survive the file.
        let mut ck = Checkpoint {
            step: 50,
            sim_seconds: 2.0,
            params: ParamMatrix::zeros(3, 2),
            velocities: None,
            gossip_clock: 10,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: Some(RoundState {
                round: 9,
                drops: 1,
                renorms: 2,
                rejoins: 0,
                alive: vec![true, false, true],
            }),
        };
        let path = tmp("rounds");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
        // 2 membership flags for 3 nodes: refuse a partial roster.
        ck.rounds = Some(RoundState {
            round: 0,
            drops: 0,
            renorms: 0,
            rejoins: 0,
            alive: vec![true, false],
        });
        assert!(ck.save(&tmp("roundsmis")).is_err());
    }

    #[test]
    fn loads_v6_files_with_no_round_block() {
        // Hand-write the v6 layout: it ends after the eventsim flag, so
        // the round machine must come back unset.
        let path = tmp("v6");
        let params = vec![4.0f32, 3.0, 2.0, 1.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&6u32.to_le_bytes());
        bytes.extend_from_slice(&11u64.to_le_bytes());
        bytes.extend_from_slice(&1.25f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        bytes.extend_from_slice(&4u64.to_le_bytes()); // gossip clock
        bytes.push(0); // no schedule
        bytes.push(0); // no slowmo
        bytes.push(0); // no rng
        bytes.push(0); // no comm
        bytes.push(0); // no ef residuals
        bytes.push(0); // no clocks
        bytes.push(0); // no eventsim; v6 files end here
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 11);
        assert_eq!(back.params.as_slice(), &params[..]);
        assert!(back.rounds.is_none(), "v6 files predate the round machine");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_future_version() {
        let path = tmp("future");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_velocity_shape_mismatch() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(2, 3),
            velocities: Some(ParamMatrix::zeros(2, 4)),
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        assert!(ck.save(&tmp("velmis")).is_err());
    }

    #[test]
    fn rejects_rng_state_count_mismatch() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(3, 2),
            velocities: None,
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: vec![[1, 2, 3, 4]; 2], // 2 states for 3 workers
            comm: None,
            ef_residuals: None,
            ef_compression: None,
            clocks: None,
            eventsim: None,
            rounds: None,
        };
        assert!(ck.save(&tmp("rngmis")).is_err());
    }
}
