//! Checkpointing: save/restore the full training state (worker parameters,
//! optimizer velocities, step counter, simulated clock) to a compact
//! binary file, so long runs resume exactly.
//!
//! Format (little-endian):
//!   magic "GPGA" | u32 version | u64 step | f64 sim_seconds |
//!   u32 n | u32 d | n * d f32 params | u8 has_velocity |
//!   [n * d f32 velocities]
//!
//! No serde offline — the writer/reader below is the substrate.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"GPGA";
const VERSION: u32 = 1;

/// A snapshot of trainer state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub sim_seconds: f64,
    /// Per-worker flat parameters (n x d).
    pub params: Vec<Vec<f32>>,
    /// Per-worker optimizer velocities (empty when momentum == 0).
    pub velocities: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let n = self.params.len();
        let d = self.params.first().map_or(0, |p| p.len());
        anyhow::ensure!(self.params.iter().all(|p| p.len() == d), "ragged params");
        let has_vel = !self.velocities.is_empty();
        if has_vel {
            anyhow::ensure!(
                self.velocities.len() == n && self.velocities.iter().all(|v| v.len() == d),
                "velocity shape mismatch"
            );
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.sim_seconds.to_le_bytes())?;
        f.write_all(&(n as u32).to_le_bytes())?;
        f.write_all(&(d as u32).to_le_bytes())?;
        for p in &self.params {
            write_f32s(&mut f, p)?;
        }
        f.write_all(&[has_vel as u8])?;
        if has_vel {
            for v in &self.velocities {
                write_f32s(&mut f, v)?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a gossip-pga checkpoint (bad magic)");
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut f)?;
        let sim_seconds = read_f64(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let d = read_u32(&mut f)? as usize;
        anyhow::ensure!(n < 1 << 20 && d < 1 << 31, "implausible checkpoint dims {n}x{d}");
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            params.push(read_f32s(&mut f, d)?);
        }
        let mut flag = [0u8; 1];
        f.read_exact(&mut flag)?;
        let velocities = if flag[0] == 1 {
            let mut vs = Vec::with_capacity(n);
            for _ in 0..n {
                vs.push(read_f32s(&mut f, d)?);
            }
            vs
        } else {
            Vec::new()
        };
        Ok(Checkpoint { step, sim_seconds, params, velocities })
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    // Bulk-write via byte view (f32 -> LE bytes; LE hosts are a straight copy).
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])).collect())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64<R: Read>(r: &mut R) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gpga_ckpt_{}_{name}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip_with_velocities() {
        let mut rng = Rng::new(1);
        let ck = Checkpoint {
            step: 1234,
            sim_seconds: 56.78,
            params: (0..3).map(|_| rng.normal_vec(17, 1.0)).collect(),
            velocities: (0..3).map(|_| rng.normal_vec(17, 0.1)).collect(),
        };
        let path = tmp("vel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_without_velocities() {
        let ck = Checkpoint {
            step: 1,
            sim_seconds: 0.0,
            params: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            velocities: Vec::new(),
        };
        let path = tmp("novel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_ragged_params() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: vec![vec![1.0], vec![1.0, 2.0]],
            velocities: Vec::new(),
        };
        assert!(ck.save(&tmp("ragged")).is_err());
    }
}
