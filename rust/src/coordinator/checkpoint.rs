//! Checkpointing: save/restore the full training state to a compact binary
//! file, so long runs resume exactly.
//!
//! "Full" means everything the trainer mutates while stepping — not just
//! parameters: worker parameters (n x d), optimizer velocities, step
//! counter, simulated clock, the mixer's gossip clock (one-peer-expo must
//! resume mid-period, not at round 0), Gossip-AGA's adaptive-period state
//! (h / counter / F_init), SlowMo's outer buffers (x_prev_sync, slow
//! momentum u), and each worker's 256-bit RNG state (so batch streams
//! continue mid-stream). A v2 checkpoint restored into a *fresh* process
//! replays bit-identically to an unbroken run.
//!
//! Format v2 (little-endian):
//!   magic "GPGA" | u32 version | u64 step | f64 sim_seconds |
//!   u32 n | u32 d | n * d f32 params | u8 has_velocity |
//!   [n * d f32 velocities] | u64 gossip_clock | u8 has_schedule |
//!   [u64 h | u64 counter | f64 f_init | u8 f_init_ready] |
//!   u8 has_slowmo | [d f32 prev | d f32 u] |
//!   u8 has_rng | [n * 4 u64 worker RNG states]
//!
//! v1 files (which end after the velocity block) still load; the extra
//! state defaults to "unset" so old checkpoints keep their old meaning
//! (callers must replay the data streams themselves, as before).
//!
//! No serde offline — the writer/reader below is the substrate.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::algorithms::AgaState;
use crate::params::ParamMatrix;

const MAGIC: &[u8; 4] = b"GPGA";
const VERSION: u32 = 2;

/// SlowMo outer-loop state (Wang et al. 2019): the parameters at the last
/// global sync and the slow-momentum buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct SlowMoState {
    pub prev: Vec<f32>,
    pub u: Vec<f32>,
}

/// A snapshot of trainer state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub sim_seconds: f64,
    /// Worker parameters, n x d.
    pub params: ParamMatrix,
    /// Optimizer velocities, n x d (None when momentum == 0 / pre-step).
    pub velocities: Option<ParamMatrix>,
    /// Gossip rounds executed (the time-varying topology's clock).
    pub gossip_clock: u64,
    /// Adaptive-schedule state (None for fixed schedules / v1 files).
    pub schedule: Option<AgaState>,
    /// SlowMo outer buffers (None for other algorithms / v1 files).
    pub slowmo: Option<SlowMoState>,
    /// Per-worker xoshiro256** states, n entries (empty for v1 files —
    /// those resumes must replay the data streams externally).
    pub rng_states: Vec<[u64; 4]>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let n = self.params.n();
        let d = self.params.d();
        if let Some(v) = &self.velocities {
            anyhow::ensure!(
                v.n() == n && v.d() == d,
                "velocity shape {}x{} mismatches params {}x{}",
                v.n(),
                v.d(),
                n,
                d
            );
        }
        if let Some(sm) = &self.slowmo {
            anyhow::ensure!(
                sm.prev.len() == d && sm.u.len() == d,
                "slowmo buffer length mismatch"
            );
        }
        anyhow::ensure!(
            self.rng_states.is_empty() || self.rng_states.len() == n,
            "rng state count {} mismatches {n} workers",
            self.rng_states.len()
        );
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&self.sim_seconds.to_le_bytes())?;
        f.write_all(&(n as u32).to_le_bytes())?;
        f.write_all(&(d as u32).to_le_bytes())?;
        write_f32s(&mut f, self.params.as_slice())?;
        f.write_all(&[self.velocities.is_some() as u8])?;
        if let Some(v) = &self.velocities {
            write_f32s(&mut f, v.as_slice())?;
        }
        f.write_all(&self.gossip_clock.to_le_bytes())?;
        f.write_all(&[self.schedule.is_some() as u8])?;
        if let Some(st) = &self.schedule {
            f.write_all(&(st.h as u64).to_le_bytes())?;
            f.write_all(&(st.counter as u64).to_le_bytes())?;
            f.write_all(&st.f_init.to_le_bytes())?;
            f.write_all(&[st.f_init_ready as u8])?;
        }
        f.write_all(&[self.slowmo.is_some() as u8])?;
        if let Some(sm) = &self.slowmo {
            write_f32s(&mut f, &sm.prev)?;
            write_f32s(&mut f, &sm.u)?;
        }
        f.write_all(&[!self.rng_states.is_empty() as u8])?;
        for st in &self.rng_states {
            for w in st {
                f.write_all(&w.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a gossip-pga checkpoint (bad magic)");
        }
        let version = read_u32(&mut f)?;
        if version == 0 || version > VERSION {
            bail!("unsupported checkpoint version {version} (this build reads 1..={VERSION})");
        }
        let step = read_u64(&mut f)?;
        let sim_seconds = read_f64(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let d = read_u32(&mut f)? as usize;
        anyhow::ensure!(n < 1 << 20 && d < 1 << 31, "implausible checkpoint dims {n}x{d}");
        let params = ParamMatrix::from_flat(n, d, read_f32s(&mut f, n * d)?);
        let velocities = if read_u8(&mut f)? == 1 {
            Some(ParamMatrix::from_flat(n, d, read_f32s(&mut f, n * d)?))
        } else {
            None
        };
        // v1 files end here; the stateful extras default to "unset".
        let (gossip_clock, schedule, slowmo, rng_states) = if version >= 2 {
            let clock = read_u64(&mut f)?;
            let schedule = if read_u8(&mut f)? == 1 {
                Some(AgaState {
                    h: read_u64(&mut f)? as usize,
                    counter: read_u64(&mut f)? as usize,
                    f_init: read_f64(&mut f)?,
                    f_init_ready: read_u8(&mut f)? == 1,
                })
            } else {
                None
            };
            let slowmo = if read_u8(&mut f)? == 1 {
                Some(SlowMoState { prev: read_f32s(&mut f, d)?, u: read_f32s(&mut f, d)? })
            } else {
                None
            };
            let rng_states = if read_u8(&mut f)? == 1 {
                let mut states = Vec::with_capacity(n);
                for _ in 0..n {
                    let mut st = [0u64; 4];
                    for w in st.iter_mut() {
                        *w = read_u64(&mut f)?;
                    }
                    states.push(st);
                }
                states
            } else {
                Vec::new()
            };
            (clock, schedule, slowmo, rng_states)
        } else {
            (0, None, None, Vec::new())
        };
        Ok(Checkpoint {
            step,
            sim_seconds,
            params,
            velocities,
            gossip_clock,
            schedule,
            slowmo,
            rng_states,
        })
    }
}

/// Elements staged per I/O chunk: checkpoints can be multi-GB (n x d at
/// BERT scale), so the byte staging buffer stays bounded (~4 MiB) instead
/// of doubling peak memory with a full-payload temporary.
const IO_CHUNK: usize = 1 << 20;

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(IO_CHUNK.min(xs.len()) * 4);
    for chunk in xs.chunks(IO_CHUNK.max(1)) {
        buf.clear();
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; IO_CHUNK.min(n.max(1)) * 4];
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(IO_CHUNK);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes)?;
        out.extend(bytes.chunks_exact(4).map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        remaining -= take;
    }
    Ok(out)
}

fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64<R: Read>(r: &mut R) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("gpga_ckpt_{}_{name}.bin", std::process::id()))
    }

    fn random_matrix(n: usize, d: usize, seed: u64, scale: f32) -> ParamMatrix {
        ParamMatrix::random(&mut Rng::new(seed), n, d, scale)
    }

    #[test]
    fn roundtrip_with_velocities() {
        let ck = Checkpoint {
            step: 1234,
            sim_seconds: 56.78,
            params: random_matrix(3, 17, 1, 1.0),
            velocities: Some(random_matrix(3, 17, 2, 0.1)),
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
        };
        let path = tmp("vel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_without_velocities() {
        let ck = Checkpoint {
            step: 1,
            sim_seconds: 0.0,
            params: ParamMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]),
            velocities: None,
            gossip_clock: 7,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
        };
        let path = tmp("novel");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_full_stateful_fields() {
        // The state-loss regression: gossip clock, AGA recursion state and
        // SlowMo outer buffers must all survive the file.
        let d = 9;
        let mut rng = Rng::new(3);
        let ck = Checkpoint {
            step: 77,
            sim_seconds: 12.5,
            params: random_matrix(4, d, 4, 1.0),
            velocities: Some(random_matrix(4, d, 5, 0.2)),
            gossip_clock: 41,
            schedule: Some(AgaState { h: 12, counter: 5, f_init: 0.6931, f_init_ready: true }),
            slowmo: Some(SlowMoState {
                prev: rng.normal_vec(d, 1.0),
                u: rng.normal_vec(d, 0.5),
            }),
            rng_states: (0..4u64).map(|i| Rng::new(i).state()).collect(),
        };
        let path = tmp("stateful");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_v1_files_with_default_extras() {
        // Hand-write the v1 layout: it ends right after the velocity block.
        let path = tmp("v1");
        let params = vec![1.0f32, 2.0, 3.0, 4.0]; // n=2, d=2
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&9u64.to_le_bytes());
        bytes.extend_from_slice(&2.5f64.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for x in &params {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(0); // no velocities
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 9);
        assert_eq!(back.params.as_slice(), &params[..]);
        assert_eq!(back.gossip_clock, 0);
        assert!(back.schedule.is_none() && back.slowmo.is_none() && back.velocities.is_none());
        assert!(back.rng_states.is_empty());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_future_version() {
        let path = tmp("future");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"GPGA");
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_velocity_shape_mismatch() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(2, 3),
            velocities: Some(ParamMatrix::zeros(2, 4)),
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: Vec::new(),
        };
        assert!(ck.save(&tmp("velmis")).is_err());
    }

    #[test]
    fn rejects_rng_state_count_mismatch() {
        let ck = Checkpoint {
            step: 0,
            sim_seconds: 0.0,
            params: ParamMatrix::zeros(3, 2),
            velocities: None,
            gossip_clock: 0,
            schedule: None,
            slowmo: None,
            rng_states: vec![[1, 2, 3, 4]; 2], // 2 states for 3 workers
        };
        assert!(ck.save(&tmp("rngmis")).is_err());
    }
}
