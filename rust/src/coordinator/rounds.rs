//! The fault-tolerant round state machine.
//!
//! A training round on a message-passing plane moves through four explicit
//! phases:
//!
//! 1. **announce** — arm the per-receive deadline on every endpoint (the
//!    round's membership and budget are declared before any byte moves);
//! 2. **gossip** — run the collective (gossip or global average) with the
//!    deadline in force;
//! 3. **collect** — classify the outcome: success, a *stalled peer* (a
//!    typed [`RecvTimeout`] naming the silent node, possibly flattened to
//!    a string by the worker pool), or a real failure;
//! 4. **commit** — on success, disarm the deadline and advance the round
//!    counter; on a stalled peer, **drop** it — fold its weight back into
//!    the mixing rows ([`CommBackend::drop_node`]), reset the message
//!    epoch so the retry discards the aborted attempt's frames
//!    ([`CommBackend::reset_round`]), and re-run the round over the
//!    degraded membership.
//!
//! The invariant the ROADMAP asked for: a late or vanished peer is
//! handled by the round protocol — timeout → renormalize the mixing row —
//! **never** by poisoning the trainer. Real failures (closed bus, length
//! mismatches, pool panics) still propagate; only attributable stalls are
//! absorbed. Every drop is counted ([`RoundMachine::drops`],
//! [`RoundMachine::renorms`]) and lands in the metrics CSV/JSON; the
//! membership snapshot rides in checkpoint v7 ([`RoundState`]) so a
//! restarted run resumes with the same degraded rows.

use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::algorithms::CommAction;
use crate::collective::stalled_peer;
use crate::comm::{CommBackend, CommCharge, CommStats};
use crate::costmodel::BarrierScope;
use crate::exec::WorkerPool;
use crate::obs::{self, Phase};
use crate::params::ParamMatrix;

/// Checkpointable snapshot of the round machine (the v7 block).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundState {
    /// Rounds committed so far.
    pub round: u64,
    /// Peers dropped by deadline (cumulative).
    pub drops: u64,
    /// Mixing rows renormalized by those drops (cumulative).
    pub renorms: u64,
    /// Peers re-admitted after a drop (cumulative).
    pub rejoins: u64,
    /// Current membership, one flag per node.
    pub alive: Vec<bool>,
}

/// Drives each communication action through the announce → gossip →
/// collect → commit phases with a per-receive deadline (see module docs).
pub struct RoundMachine {
    n: usize,
    timeout: Duration,
    /// Rounds committed so far.
    pub round: u64,
    /// Membership as this machine believes it (kept in lockstep with the
    /// backend's mask via drop/rejoin).
    pub alive: Vec<bool>,
    pub drops: u64,
    pub renorms: u64,
    pub rejoins: u64,
}

impl RoundMachine {
    /// A machine for `n` nodes with a per-receive deadline of
    /// `timeout_secs` (must be finite and positive).
    pub fn new(n: usize, timeout_secs: f64) -> Result<RoundMachine> {
        ensure!(
            timeout_secs.is_finite() && timeout_secs > 0.0,
            "round timeout must be a positive number of seconds, got {timeout_secs}"
        );
        ensure!(n > 0, "round machine needs at least one node");
        Ok(RoundMachine {
            n,
            timeout: Duration::from_secs_f64(timeout_secs),
            round: 0,
            alive: vec![true; n],
            drops: 0,
            renorms: 0,
            rejoins: 0,
        })
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Run one action through the phased protocol. Stalled peers are
    /// dropped and the action retried over the degraded membership (at
    /// most n-1 times — every retry removes a node); any other error
    /// propagates with the deadline disarmed.
    pub fn run(
        &mut self,
        action: CommAction,
        backend: &mut dyn CommBackend,
        params: &mut ParamMatrix,
        pool: &WorkerPool,
    ) -> Result<CommCharge> {
        if action == CommAction::None {
            self.round += 1;
            return Ok(CommCharge {
                stats: CommStats::default(),
                node_seconds: vec![0.0; self.n],
                barrier: BarrierScope::None,
            });
        }
        // Announce: the deadline is the round's membership budget.
        {
            let _sp = obs::span(Phase::RoundAnnounce, obs::CLUSTER);
            backend.set_recv_deadline(Some(self.timeout));
        }
        let result = loop {
            ensure!(
                self.alive.iter().any(|&a| a),
                "round {}: every peer has dropped out",
                self.round
            );
            // Gossip: the collective itself, deadline in force.
            let attempt = {
                let mut sp = obs::span(Phase::RoundGossip, obs::CLUSTER);
                let attempt = match action {
                    CommAction::Gossip => backend.gossip(params, pool),
                    CommAction::GlobalAverage => backend.global_average(params, pool),
                    CommAction::None => unreachable!("handled above"),
                };
                if let Ok(charge) = &attempt {
                    sp.set_sim(charge.stats.sim_seconds);
                }
                attempt
            };
            // Collect: classify the outcome (spans the drop/renorm/reset
            // repair when a peer stalled; near-zero on a clean round).
            let _collect = obs::span(Phase::RoundCollect, obs::CLUSTER);
            match attempt {
                Ok(charge) => break Ok(charge),
                Err(e) => {
                    let text = format!("{e:#}");
                    match stalled_peer(&text) {
                        Some(p) if p < self.n && self.alive[p] => {
                            // Commit the drop: renormalize, reset, retry.
                            self.alive[p] = false;
                            self.drops += 1;
                            self.renorms += backend.drop_node(p)?;
                            backend.reset_round();
                        }
                        _ => break Err(e),
                    }
                }
            }
        };
        // Commit: disarm; only a successful round advances the counter.
        {
            let _sp = obs::span(Phase::RoundCommit, obs::CLUSTER);
            backend.set_recv_deadline(None);
            if result.is_ok() {
                self.round += 1;
            }
        }
        result
    }

    /// Re-admit a dropped node (its pristine mixing weight folds back in).
    pub fn rejoin(&mut self, node: usize, backend: &mut dyn CommBackend) -> Result<()> {
        ensure!(node < self.n, "rejoin {node} out of range for n={}", self.n);
        ensure!(!self.alive[node], "node {node} is not dropped");
        backend.rejoin_node(node)?;
        self.alive[node] = true;
        self.rejoins += 1;
        Ok(())
    }

    /// Snapshot for checkpoint v7.
    pub fn state(&self) -> RoundState {
        RoundState {
            round: self.round,
            drops: self.drops,
            renorms: self.renorms,
            rejoins: self.rejoins,
            alive: self.alive.clone(),
        }
    }

    /// Restore a snapshot, re-applying every recorded drop to `backend`
    /// (the renorm counter keeps the checkpointed value — the folds were
    /// already counted when they first happened).
    pub fn restore(
        &mut self,
        state: &RoundState,
        backend: &mut dyn CommBackend,
    ) -> Result<()> {
        ensure!(
            state.alive.len() == self.n,
            "round state covers {} nodes, run has {}",
            state.alive.len(),
            self.n
        );
        // Roll the backend's membership to match the snapshot.
        let current = backend
            .alive_mask()
            .unwrap_or_else(|| vec![true; self.n]);
        for (node, (&want, &have)) in state.alive.iter().zip(&current).enumerate() {
            match (want, have) {
                (false, true) => {
                    backend.drop_node(node)?;
                }
                (true, false) => {
                    backend.rejoin_node(node)?;
                }
                _ => {}
            }
        }
        self.round = state.round;
        self.drops = state.drops;
        self.renorms = state.renorms;
        self.rejoins = state.rejoins;
        self.alive = state.alive.clone();
        Ok(())
    }
}

/// A machine cannot run on a plane that cannot time out.
pub fn require_deadline_support(backend: &dyn CommBackend) -> Result<()> {
    if !backend.supports_deadlines() {
        bail!(
            "--round-timeout needs a deadline-capable backend (bus | tcp), \
             the {} backend has no wire to time out on",
            backend.kind().name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{BusBackend, Compression};
    use crate::costmodel::{CostModel, NodeCosts};
    use crate::topology::Topology;

    fn backend(n: usize, d: usize, with_global: bool) -> BusBackend {
        let costs =
            NodeCosts::homogeneous(CostModel { alpha: 1e-4, theta: 1e-8, compute: 0.0 }, n);
        BusBackend::new(&Topology::ring(n), d, &costs, d, Compression::None, with_global)
    }

    fn ramp(n: usize, d: usize) -> ParamMatrix {
        let mut p = ParamMatrix::zeros(n, d);
        for i in 0..n {
            for (j, v) in p.row_mut(i).iter_mut().enumerate() {
                *v = (i * d + j) as f32 * 0.125;
            }
        }
        p
    }

    #[test]
    fn healthy_rounds_commit_and_count() {
        let (n, d) = (4, 6);
        let mut b = backend(n, d, true);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        let mut m = RoundMachine::new(n, 5.0).unwrap();
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        m.run(CommAction::GlobalAverage, &mut b, &mut params, &pool).unwrap();
        m.run(CommAction::None, &mut b, &mut params, &pool).unwrap();
        assert_eq!((m.round, m.drops, m.renorms), (3, 0, 0));
        assert_eq!(m.alive_count(), n);
    }

    #[test]
    fn stalled_peer_is_dropped_and_round_completes() {
        // The acceptance scenario: node 2 wedges mid-round; the machine
        // must finish the round over n-1 nodes, count the drop, and leave
        // the trainer unpoisoned.
        let (n, d) = (5, 8);
        let mut b = backend(n, d, false);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        b.set_muted(2, true).unwrap();
        let mut m = RoundMachine::new(n, 0.05).unwrap();
        let charge = m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        assert_eq!((m.round, m.drops), (1, 1));
        assert_eq!(m.renorms, 2, "ring neighbors 1 and 3 renormalized");
        assert_eq!(m.alive, vec![true, true, false, true, true]);
        assert!(charge.stats.msgs > 0, "the retried round really communicated");
        // The next round runs healthy — no deadline armed, no poison.
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        assert_eq!(m.round, 2);
    }

    #[test]
    fn rejoin_restores_membership_and_counts() {
        let (n, d) = (4, 4);
        let mut b = backend(n, d, false);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        b.set_muted(3, true).unwrap();
        let mut m = RoundMachine::new(n, 0.05).unwrap();
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        assert!(!m.alive[3]);
        m.rejoin(3, &mut b).unwrap();
        assert!(m.alive[3] && m.rejoins == 1);
        assert!(m.rejoin(3, &mut b).is_err(), "double rejoin refused");
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        assert_eq!(m.alive_count(), n, "full membership after rejoin");
    }

    #[test]
    fn real_failures_still_propagate() {
        // A pure-gossip backend asked for a global average is a config
        // error, not a stall: no drop, error surfaces, deadline disarmed.
        let (n, d) = (3, 4);
        let mut b = backend(n, d, false);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        let mut m = RoundMachine::new(n, 0.05).unwrap();
        let err = m.run(CommAction::GlobalAverage, &mut b, &mut params, &pool).unwrap_err();
        assert!(format!("{err}").contains("without all-reduce edges"));
        assert_eq!((m.drops, m.round), (0, 0));
        // The config error did not poison anything: gossip still runs.
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
    }

    #[test]
    fn state_snapshot_restores_membership_onto_a_fresh_backend() {
        let (n, d) = (5, 6);
        let mut b = backend(n, d, false);
        let pool = WorkerPool::new(1);
        let mut params = ramp(n, d);
        b.set_muted(1, true).unwrap();
        let mut m = RoundMachine::new(n, 0.05).unwrap();
        m.run(CommAction::Gossip, &mut b, &mut params, &pool).unwrap();
        let snap = m.state();
        assert_eq!(snap.alive, vec![true, false, true, true, true]);

        // A restarted process: fresh backend, fresh machine, same state.
        let mut b2 = backend(n, d, false);
        let mut m2 = RoundMachine::new(n, 0.05).unwrap();
        m2.restore(&snap, &mut b2).unwrap();
        assert_eq!(m2.state(), snap);
        assert_eq!(b2.alive_mask().unwrap(), snap.alive);
        // And it trains: the degraded round completes without a timeout.
        m2.run(CommAction::Gossip, &mut b2, &mut params, &pool).unwrap();
    }

    #[test]
    fn deadline_support_is_required() {
        use crate::comm::SharedBackend;
        let topo = Topology::ring(3);
        let costs =
            NodeCosts::homogeneous(CostModel { alpha: 1e-4, theta: 1e-8, compute: 0.0 }, 3);
        let shared = SharedBackend::new(&topo, 4, &costs, 4, Compression::None);
        let err = require_deadline_support(&shared).unwrap_err().to_string();
        assert!(err.contains("shared"), "{err}");
        let bus = backend(3, 4, false);
        require_deadline_support(&bus).unwrap();
    }
}
