//! Experiment configuration system.
//!
//! A TOML-subset parser ([`Toml`]) plus the typed [`ExperimentConfig`] that
//! the launcher (`gossip-pga train`) and the benches consume. Supported
//! syntax: `[section.sub]` headers, `key = value` with strings, integers,
//! floats, booleans and flat arrays, `#` comments.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::algorithms::AlgorithmKind;
use crate::comm::{BackendKind, Compression};
use crate::costmodel::{CostModel, NodeCosts};
use crate::eventsim::Regime;
use crate::topology::Topology;

/// A parsed TOML-subset document: dotted-path -> value.
#[derive(Clone, Debug, Default)]
pub struct Toml {
    pub values: BTreeMap<String, Value>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(section) = line.strip_prefix('[') {
                let section =
                    section.strip_suffix(']').ok_or_else(|| anyhow!("line {}: bad section", lineno + 1))?;
                prefix = section.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let path = if prefix.is_empty() {
                key.trim().to_string()
            } else {
                format!("{prefix}.{}", key.trim())
            };
            let v = parse_value(value.trim())
                .with_context(|| format!("line {}: value for '{path}'", lineno + 1))?;
            doc.values.insert(path, v);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Toml> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Toml::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| anyhow!("'{key}' must be a non-negative integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| anyhow!("'{key}' must be numeric")),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v.as_str().ok_or_else(|| anyhow!("'{key}' must be a string"))?.to_string()),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| anyhow!("'{key}' must be a bool")),
        }
    }

    /// A numeric key that may be a scalar (`k = 0.1`, one value) or a flat
    /// array (`k = [0.1, 0.2]`, one per node). Absent => empty.
    pub fn get_f64_list(&self, key: &str) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(Vec::new()),
            Some(Value::Arr(items)) => items
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| anyhow!("'{key}' entries must be numeric"))
                })
                .collect(),
            Some(v) => {
                Ok(vec![v.as_f64().ok_or_else(|| anyhow!("'{key}' must be numeric"))?])
            }
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

/// Typed experiment configuration consumed by the launcher and benches.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Number of nodes.
    pub nodes: usize,
    /// Topology name (see [`Topology::from_name`]).
    pub topology: String,
    /// Algorithm (parallel | gossip | local | pga | aga | slowmo).
    pub algorithm: AlgorithmKind,
    /// Global averaging period H.
    pub period: usize,
    /// AGA initial period / warmup iterations.
    pub aga_init_period: usize,
    pub aga_warmup: usize,
    /// Model artifact name prefix ("logreg", "mlp", "transformer").
    pub model: String,
    /// Transformer config tag when model == transformer.
    pub model_tag: String,
    pub steps: usize,
    pub lr: f64,
    pub lr_decay_every: usize,
    pub lr_decay_factor: f64,
    pub warmup_steps: usize,
    pub momentum: f64,
    pub nesterov: bool,
    pub seed: u64,
    /// Data heterogeneity: true = non-iid (per-node distributions).
    pub non_iid: bool,
    pub samples_per_node: usize,
    pub batch: usize,
    pub log_every: usize,
    /// Size of the persistent worker pool the per-step phases, the
    /// row-parallel mix and the eval pass shard across (1 = sequential;
    /// results are bit-identical at any value).
    pub threads: usize,
    /// Work-stealing dynamic chunking in the worker pool (heterogeneous
    /// workers); bit-identical to static sharding, off by default.
    pub stealing: bool,
    /// Pin pool worker threads to cores (`train.pin` / `--pin`): worker i
    /// to core `i % available cores`, keeping each thread's row shard
    /// cache-local across rounds. Best-effort where affinity calls fail
    /// (warns once, runs unpinned); bit-identical either way.
    pub pin: bool,
    /// Max gossip rounds in flight on any backend's async pipeline —
    /// shared, bus, and tcp all overlap uncompressed gossip
    /// (`train.pipeline_depth` / `--pipeline-depth`); 1 = the classic
    /// double buffer (default). Drained FIFO at every k·H / eval /
    /// checkpoint boundary, bit-identical to BSP at every drained point.
    pub pipeline_depth: usize,
    /// Per-node cost-model overrides (`cost.alpha` / `cost.theta` /
    /// `cost.compute`): empty = the calibrated default on every node, one
    /// value = that value on every node, n values = node i's value.
    pub cost_alpha: Vec<f64>,
    pub cost_theta: Vec<f64>,
    pub cost_compute: Vec<f64>,
    /// Straggler specs parsed from `cost.straggler` / `--straggler`
    /// ("idx:factor[,idx:factor...]"): node idx's compute and alpha scale
    /// by factor (see [`NodeCosts::with_straggler`]).
    pub stragglers: Vec<(usize, f64)>,
    /// Double-buffered async gossip: overlap the round-t mix with round
    /// t+1's sampling phase (bit-identical to BSP at every global-averaging
    /// boundary). Off by default; shorthand for `train.regime = "overlap"`.
    pub overlap: bool,
    /// Execution regime: "bsp" (default), "overlap", or "async" — the
    /// event-driven AD-PSGD plane (`eventsim`). Defaults to "overlap" when
    /// only `train.overlap = true` is set (back-compat).
    pub regime: String,
    /// Async regime: how many versions behind BSP-fresh a mix input may
    /// be. 0 = strict (bit-identical to BSP); >= 1 overlaps compute with
    /// in-flight transfers.
    pub max_staleness: usize,
    /// Communication backend: "shared" (in-proc mixer, default), "bus"
    /// (message-passing endpoints with measured traffic), or "tcp" (the
    /// same bus core over real loopback sockets).
    pub backend: String,
    /// TCP backend: the `host:port` every rank's listener binds
    /// (`comm.listen` / `--listen`). Port 0 = OS-assigned (the default);
    /// a fixed port P pins rank r to P + r.
    pub listen: String,
    /// TCP backend: remote peer addresses for a multi-process deployment
    /// (`comm.peers` / `--peers`). Not yet supported — a non-empty list is
    /// rejected at validation with a clear message; the loopback shape
    /// (every rank in this process) is the one that ships.
    pub peers: Vec<String>,
    /// Per-receive deadline in seconds for the fault-tolerant round state
    /// machine (`comm.round_timeout` / `--round-timeout`): a peer silent
    /// past this budget is dropped by renormalizing its mixing row. 0 =
    /// off (the default). Needs a deadline-capable backend (bus | tcp).
    pub round_timeout: f64,
    /// Gossip compression: "none" (default), "topk" or "int8".
    pub compression: String,
    /// Fraction of coordinates top-k keeps (when compression = "topk").
    pub topk_frac: f64,
    /// Quantization block size (when compression = "int8").
    pub int8_block: usize,
    /// Trace output path (`trace.path` / `--trace out.json`): write the
    /// run's per-phase span timeline as Chrome trace-event JSON. Empty
    /// (the default) = tracing off — the probes are no-ops and the run
    /// is byte-for-byte the untraced one.
    pub trace_path: String,
    /// Per-worker span ring capacity (`trace.capacity`): oldest spans are
    /// evicted past this, counted in the `spans_dropped` counter.
    pub trace_capacity: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            nodes: 8,
            topology: "ring".into(),
            algorithm: AlgorithmKind::GossipPga,
            period: 16,
            aga_init_period: 4,
            aga_warmup: 50,
            model: "logreg".into(),
            model_tag: "tiny".into(),
            steps: 500,
            lr: 0.2,
            lr_decay_every: 1000,
            lr_decay_factor: 0.5,
            warmup_steps: 0,
            momentum: 0.0,
            nesterov: false,
            seed: 42,
            non_iid: true,
            samples_per_node: 8000,
            batch: 32,
            log_every: 50,
            threads: 1,
            stealing: false,
            pin: false,
            pipeline_depth: 1,
            cost_alpha: Vec::new(),
            cost_theta: Vec::new(),
            cost_compute: Vec::new(),
            stragglers: Vec::new(),
            overlap: false,
            regime: "bsp".into(),
            max_staleness: 0,
            backend: "shared".into(),
            listen: "127.0.0.1:0".into(),
            peers: Vec::new(),
            round_timeout: 0.0,
            compression: "none".into(),
            topk_frac: 0.1,
            int8_block: 1024,
            trace_path: String::new(),
            trace_capacity: 65536,
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(doc: &Toml) -> Result<Self> {
        let d = ExperimentConfig::default();
        let cfg = ExperimentConfig {
            nodes: doc.get_usize("cluster.nodes", d.nodes)?,
            topology: doc.get_str("cluster.topology", &d.topology)?,
            algorithm: AlgorithmKind::from_name(&doc.get_str("algorithm.name", "pga")?)?,
            period: doc.get_usize("algorithm.period", d.period)?,
            aga_init_period: doc.get_usize("algorithm.aga_init_period", d.aga_init_period)?,
            aga_warmup: doc.get_usize("algorithm.aga_warmup", d.aga_warmup)?,
            model: doc.get_str("model.name", &d.model)?,
            model_tag: doc.get_str("model.tag", &d.model_tag)?,
            steps: doc.get_usize("train.steps", d.steps)?,
            lr: doc.get_f64("train.lr", d.lr)?,
            lr_decay_every: doc.get_usize("train.lr_decay_every", d.lr_decay_every)?,
            lr_decay_factor: doc.get_f64("train.lr_decay_factor", d.lr_decay_factor)?,
            warmup_steps: doc.get_usize("train.warmup_steps", d.warmup_steps)?,
            momentum: doc.get_f64("train.momentum", d.momentum)?,
            nesterov: doc.get_bool("train.nesterov", d.nesterov)?,
            seed: doc.get_usize("train.seed", d.seed as usize)? as u64,
            non_iid: doc.get_bool("data.non_iid", d.non_iid)?,
            samples_per_node: doc.get_usize("data.samples_per_node", d.samples_per_node)?,
            batch: doc.get_usize("data.batch", d.batch)?,
            log_every: doc.get_usize("train.log_every", d.log_every)?,
            threads: doc.get_usize("train.threads", d.threads)?,
            stealing: doc.get_bool("train.stealing", d.stealing)?,
            pin: doc.get_bool("train.pin", d.pin)?,
            pipeline_depth: doc.get_usize("train.pipeline_depth", d.pipeline_depth)?,
            cost_alpha: doc.get_f64_list("cost.alpha")?,
            cost_theta: doc.get_f64_list("cost.theta")?,
            cost_compute: doc.get_f64_list("cost.compute")?,
            stragglers: parse_stragglers(&doc.get_str("cost.straggler", "")?)?,
            overlap: doc.get_bool("train.overlap", d.overlap)?,
            regime: match doc.get("train.regime") {
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| anyhow!("'train.regime' must be a string"))?
                    .to_string(),
                // Back-compat: a bare `train.overlap = true` selects the
                // overlap regime.
                None => {
                    if doc.get_bool("train.overlap", d.overlap)? {
                        "overlap".into()
                    } else {
                        d.regime.clone()
                    }
                }
            },
            max_staleness: doc.get_usize("train.max_staleness", d.max_staleness)?,
            backend: doc.get_str("comm.backend", &d.backend)?,
            listen: doc.get_str("comm.listen", &d.listen)?,
            peers: match doc.get("comm.peers") {
                None => Vec::new(),
                Some(Value::Arr(items)) => items
                    .iter()
                    .map(|v| {
                        v.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow!("'comm.peers' entries must be \"host:port\" strings")
                        })
                    })
                    .collect::<Result<_>>()?,
                Some(v) => vec![v
                    .as_str()
                    .ok_or_else(|| {
                        anyhow!("'comm.peers' must be a string or an array of strings")
                    })?
                    .to_string()],
            },
            round_timeout: doc.get_f64("comm.round_timeout", d.round_timeout)?,
            compression: doc.get_str("comm.compression", &d.compression)?,
            topk_frac: doc.get_f64("comm.topk_frac", d.topk_frac)?,
            int8_block: doc.get_usize("comm.int8_block", d.int8_block)?,
            trace_path: doc.get_str("trace.path", &d.trace_path)?,
            trace_capacity: doc.get_usize("trace.capacity", d.trace_capacity)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.nodes >= 1, "nodes must be >= 1");
        // H = 0 would hit `(k + 1) % 0` in the schedule — reject here (and
        // again in FixedSchedule::for_kind for non-config construction).
        anyhow::ensure!(self.period >= 1, "period H must be >= 1 (got 0)");
        anyhow::ensure!(
            self.aga_init_period >= 1,
            "aga_init_period H_init must be >= 1 (got 0)"
        );
        anyhow::ensure!(self.steps >= 1, "steps must be >= 1");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!((0.0..1.0).contains(&self.momentum), "momentum in [0,1)");
        anyhow::ensure!(self.threads >= 1, "threads must be >= 1");
        anyhow::ensure!(
            self.pipeline_depth >= 1,
            "train.pipeline_depth must be >= 1 (1 = the classic double buffer)"
        );
        if self.pin {
            let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
            if self.threads > cores {
                bail!(
                    "train.pin wants train.threads <= available cores ({cores}) — pinning \
                     {} threads would stack several on one core and defeat the point \
                     (drop --pin, or lower --threads)",
                    self.threads
                );
            }
        }
        // Cost overrides: a non-finite or non-positive alpha/theta/compute
        // would silently produce NaN/negative sim clocks downstream —
        // reject here (same treatment period/H_init/threads = 0 get).
        // Deliberately stricter than NodeCosts::validate, which admits
        // compute == 0 for programmatic pure-communication tables: a
        // config-supplied zero is far more likely a typo'd unit than an
        // analytic-table intent, so the user-facing path refuses it.
        for (key, list) in [
            ("cost.alpha", &self.cost_alpha),
            ("cost.theta", &self.cost_theta),
            ("cost.compute", &self.cost_compute),
        ] {
            if !(list.is_empty() || list.len() == 1 || list.len() == self.nodes) {
                bail!(
                    "'{key}' wants 1 or {} entries (one per node), got {}",
                    self.nodes,
                    list.len()
                );
            }
            for (i, x) in list.iter().enumerate() {
                if !(x.is_finite() && *x > 0.0) {
                    bail!("'{key}[{i}]' must be finite and positive, got {x}");
                }
            }
        }
        for &(idx, factor) in &self.stragglers {
            if idx >= self.nodes {
                bail!("straggler index {idx} out of range for {} nodes", self.nodes);
            }
            if !(factor.is_finite() && factor > 0.0) {
                bail!("straggler factor must be finite and positive, got {factor}");
            }
        }
        Topology::from_name(&self.topology, self.nodes)?;
        let backend = self.backend_kind()?;
        if !self.peers.is_empty() {
            // The loopback shape (every rank in this process) is the one
            // that ships; a multi-process mesh needs a join handshake on
            // top of the same frames.
            bail!(
                "comm.peers: a multi-process tcp deployment is not yet supported — \
                 the tcp backend runs every rank in this process over loopback \
                 (drop comm.peers; use comm.listen to pick the bind address)"
            );
        }
        if backend == BackendKind::Tcp && !self.listen.contains(':') {
            bail!("comm.listen wants host:port (port 0 = OS-assigned), got '{}'", self.listen);
        }
        anyhow::ensure!(
            self.round_timeout.is_finite() && self.round_timeout >= 0.0,
            "comm.round_timeout must be a non-negative number of seconds, got {}",
            self.round_timeout
        );
        if self.round_timeout > 0.0 && backend == BackendKind::Shared {
            bail!(
                "comm.round_timeout needs a deadline-capable backend (bus | tcp) — \
                 the shared-memory mixer has no wire to time out on"
            );
        }
        self.compression_kind()?;
        // Tracing: a zero-capacity ring can hold no span at all — every
        // probe would evict itself, which is never what the user meant.
        anyhow::ensure!(
            self.trace_capacity >= 1,
            "trace.capacity must be >= 1 spans per worker ring (got 0) — \
             shrink the traced window instead of the ring"
        );
        let regime = self.regime_kind()?;
        if self.overlap && regime != Regime::Overlap {
            bail!(
                "train.overlap = true conflicts with train.regime = \"{}\"",
                self.regime
            );
        }
        if self.max_staleness > 0 && regime != Regime::Async {
            bail!("train.max_staleness only applies to train.regime = \"async\"");
        }
        if self.pipeline_depth > 1 && regime == Regime::Async {
            bail!(
                "train.pipeline_depth > 1 only applies to the bsp/overlap regimes — \
                 the async event plane schedules its own in-flight rounds"
            );
        }
        Ok(())
    }

    /// Parsed execution regime ([`Regime`]).
    pub fn regime_kind(&self) -> Result<Regime> {
        Regime::from_name(&self.regime)
    }

    /// Resolve the per-node cost table from the overrides + straggler
    /// specs over `base`. `None` when nothing is overridden — the
    /// homogeneous path whose clocks reproduce the scalar `sim_seconds`
    /// bit-exactly.
    pub fn node_costs(&self, base: CostModel) -> Result<Option<NodeCosts>> {
        if self.cost_alpha.is_empty()
            && self.cost_theta.is_empty()
            && self.cost_compute.is_empty()
            && self.stragglers.is_empty()
        {
            return Ok(None);
        }
        let mut costs = NodeCosts::homogeneous(base, self.nodes);
        spread_override(&self.cost_alpha, &mut costs.alpha, "cost.alpha")?;
        spread_override(&self.cost_theta, &mut costs.theta, "cost.theta")?;
        spread_override(&self.cost_compute, &mut costs.compute, "cost.compute")?;
        for &(idx, factor) in &self.stragglers {
            costs = costs.with_straggler(idx, factor)?;
        }
        costs.validate()?;
        Ok(Some(costs))
    }

    pub fn topology(&self) -> Topology {
        Topology::from_name(&self.topology, self.nodes).expect("validated")
    }

    /// Parsed communication backend ([`BackendKind`]).
    pub fn backend_kind(&self) -> Result<BackendKind> {
        BackendKind::from_name(&self.backend)
    }

    /// Parsed gossip compression ([`Compression`]).
    pub fn compression_kind(&self) -> Result<Compression> {
        Compression::from_parts(&self.compression, self.topk_frac, self.int8_block)
    }
}

/// Configuration of a population sweep (`gossip-pga sweep`): the
/// virtual-plane counterpart of [`ExperimentConfig`]. Assembled from CLI
/// flags by the launcher; [`SweepConfig::validate`] is the front door that
/// rejects bad knobs (out-of-range stragglers, conflicting payload modes,
/// malformed region specs) before any engine state is built.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Population size (`--virtual-n`) — nodes simulated, none materialized.
    pub virtual_n: usize,
    pub topology: String,
    pub algorithm: AlgorithmKind,
    /// Global averaging period H.
    pub period: usize,
    /// Iterations every live node must complete.
    pub steps: usize,
    pub max_staleness: usize,
    /// `--surrogate`: statistical `(mean, var)` payloads — no dense scalar
    /// is ever allocated. Mutually exclusive with `dim > 0`.
    pub surrogate: bool,
    /// Dense drift dimension (`--dim`); 0 with `surrogate` unset also
    /// selects the surrogate (the zero-dimensional drift IS the surrogate).
    pub dim: usize,
    pub seed: u64,
    /// Billing dimension of the alpha-beta cost model (`--cost-dim`).
    pub cost_dim: usize,
    /// Explicit churn script (`--churn "crash@t:n,..."`); empty = none.
    pub churn: String,
    /// Seeded churn: number of crash/flaky disturbance pairs
    /// (`--churn-pairs`, 0 = none) drawn from `--churn-seed` over
    /// `--churn-horizon` virtual seconds.
    pub churn_pairs: usize,
    pub churn_seed: u64,
    pub churn_horizon: f64,
    /// Region latency tiers (`--regions k:mult`): k contiguous regions,
    /// cross-region links slowed by mult. Empty = flat.
    pub regions: String,
    /// `--straggler idx:factor` specs (validated against `virtual_n`).
    pub stragglers: Vec<(usize, f64)>,
    /// Curve resolution (`--log-points`).
    pub log_points: usize,
    /// Report output path (`--report`); empty = print to stdout only.
    pub report: String,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            virtual_n: 1024,
            topology: "one-peer-expo".into(),
            algorithm: AlgorithmKind::GossipPga,
            period: 8,
            steps: 64,
            max_staleness: 2,
            surrogate: false,
            dim: 0,
            seed: 42,
            cost_dim: 25_500_000,
            churn: String::new(),
            churn_pairs: 0,
            churn_seed: 42,
            churn_horizon: 0.0,
            regions: String::new(),
            stragglers: Vec::new(),
            log_points: 20,
            report: String::new(),
        }
    }
}

impl SweepConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.virtual_n >= 1, "--virtual-n must be >= 1");
        anyhow::ensure!(self.period >= 1, "period H must be >= 1 (got 0)");
        anyhow::ensure!(self.steps >= 1, "--steps must be >= 1");
        anyhow::ensure!(self.log_points >= 1, "--log-points must be >= 1");
        anyhow::ensure!(self.cost_dim >= 1, "--cost-dim must be >= 1");
        Topology::from_name(&self.topology, self.virtual_n)?;
        if self.surrogate && self.dim > 0 {
            bail!(
                "--surrogate conflicts with --dim {} (surrogate payloads carry no dense state)",
                self.dim
            );
        }
        // The sweep-path straggler range check: the train path has bailed
        // on out-of-range indices since PR 4 (ExperimentConfig::validate /
        // NodeCosts::with_straggler); the sweep's population size comes
        // from a different flag, so it needs its own front-door message.
        for &(idx, factor) in &self.stragglers {
            if idx >= self.virtual_n {
                bail!(
                    "--straggler index {idx} out of range for the virtual population \
                     (--virtual-n {}; valid indices are 0..{})",
                    self.virtual_n,
                    self.virtual_n
                );
            }
            if !(factor.is_finite() && factor > 0.0) {
                bail!("straggler factor must be finite and positive, got {factor}");
            }
        }
        if self.churn_pairs > 0 {
            anyhow::ensure!(
                self.churn_horizon.is_finite() && self.churn_horizon > 0.0,
                "--churn-pairs needs a positive --churn-horizon (virtual seconds)"
            );
            anyhow::ensure!(
                self.virtual_n >= 2,
                "seeded churn needs at least 2 virtual nodes"
            );
        }
        self.region_spec()?;
        Ok(())
    }

    /// Parse `--regions k:mult` into `(k, cross_region_multiplier)`.
    /// Empty => `None` (a flat, single-region population).
    pub fn region_spec(&self) -> Result<Option<(usize, f64)>> {
        let spec = self.regions.trim();
        if spec.is_empty() {
            return Ok(None);
        }
        let (k, mult) = spec
            .split_once(':')
            .ok_or_else(|| anyhow!("--regions wants k:mult (e.g. 4:10), got '{spec}'"))?;
        let k: usize = k
            .trim()
            .parse()
            .map_err(|_| anyhow!("--regions region count must be an integer, got '{k}'"))?;
        let mult: f64 = mult
            .trim()
            .parse()
            .map_err(|_| anyhow!("--regions multiplier must be numeric, got '{mult}'"))?;
        anyhow::ensure!(
            k >= 1 && k <= self.virtual_n,
            "--regions count {k} must be in 1..={}",
            self.virtual_n
        );
        anyhow::ensure!(
            mult.is_finite() && mult > 0.0,
            "--regions multiplier must be finite and positive, got {mult}"
        );
        Ok(Some((k, mult)))
    }
}

/// Apply a scalar-or-per-node override list onto a resolved table.
fn spread_override(list: &[f64], out: &mut [f64], key: &str) -> Result<()> {
    match list.len() {
        0 => Ok(()),
        1 => {
            out.fill(list[0]);
            Ok(())
        }
        l if l == out.len() => {
            out.copy_from_slice(list);
            Ok(())
        }
        l => bail!("'{key}' wants 1 or {} entries (one per node), got {l}", out.len()),
    }
}

/// Parse straggler specs: "idx:factor" entries separated by commas, e.g.
/// `--straggler 3:4` or `cost.straggler = "1:2.5,6:8"`. Empty => none.
pub fn parse_stragglers(spec: &str) -> Result<Vec<(usize, f64)>> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Ok(Vec::new());
    }
    let mut seen = std::collections::BTreeSet::new();
    spec.split(',')
        .map(|part| {
            let part = part.trim();
            let (idx, factor) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("straggler spec wants idx:factor, got '{part}'"))?;
            let idx: usize = idx
                .trim()
                .parse()
                .map_err(|_| anyhow!("straggler index must be an integer, got '{idx}'"))?;
            let factor: f64 = factor
                .trim()
                .parse()
                .map_err(|_| anyhow!("straggler factor must be numeric, got '{factor}'"))?;
            // Silently compounding two specs for one node (factor a then
            // factor b => a*b) is never what the user meant — reject.
            if !seen.insert(idx) {
                bail!("duplicate straggler index {idx} (each node may appear once)");
            }
            Ok((idx, factor))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = Toml::parse(
            r#"
            # experiment
            top = "ring"
            [cluster]
            nodes = 20         # inline comment
            frac = 0.5
            flag = true
            arr = [1, 2, 3]
            [a.b]
            s = "x # not a comment"
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_str().unwrap(), "ring");
        assert_eq!(doc.get("cluster.nodes").unwrap().as_usize().unwrap(), 20);
        assert_eq!(doc.get("cluster.frac").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(doc.get("cluster.flag").unwrap().as_bool().unwrap(), true);
        assert_eq!(
            doc.get("cluster.arr").unwrap(),
            &Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(doc.get("a.b.s").unwrap().as_str().unwrap(), "x # not a comment");
    }

    #[test]
    fn parse_rejects_bad_lines() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("novalue").is_err());
        assert!(Toml::parse("k = ").is_err());
        assert!(Toml::parse("k = \"open").is_err());
    }

    #[test]
    fn experiment_defaults_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn experiment_from_toml_overrides() {
        let doc = Toml::parse(
            r#"
            [cluster]
            nodes = 20
            topology = "grid"
            [algorithm]
            name = "gossip"
            [train]
            steps = 100
            lr = 0.05
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.nodes, 20);
        assert_eq!(cfg.topology, "grid");
        assert_eq!(cfg.algorithm, AlgorithmKind::Gossip);
        assert_eq!(cfg.steps, 100);
        assert!((cfg.lr - 0.05).abs() < 1e-12);
        // untouched default
        assert_eq!(cfg.batch, 32);
    }

    #[test]
    fn experiment_validation_rejects() {
        let mut cfg = ExperimentConfig::default();
        cfg.period = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.aga_init_period = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.threads = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.topology = "nonsense".into();
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.momentum = 1.5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn threads_parse_from_toml() {
        let doc = Toml::parse("[train]\nthreads = 4\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.threads, 4);
        // default is sequential
        assert_eq!(ExperimentConfig::default().threads, 1);
        let doc = Toml::parse("[train]\nthreads = 0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn comm_backend_parse_from_toml() {
        let doc = Toml::parse("[comm]\nbackend = \"bus\"\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.backend_kind().unwrap(), BackendKind::Bus);
        // default is the shared-memory mixer
        assert_eq!(ExperimentConfig::default().backend_kind().unwrap(), BackendKind::Shared);
        let doc = Toml::parse("[comm]\nbackend = \"smoke-signals\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn comm_compression_parse_from_toml() {
        let doc = Toml::parse("[comm]\ncompression = \"topk\"\ntopk_frac = 0.25\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.compression_kind().unwrap(), Compression::TopK { frac: 0.25 });
        let doc = Toml::parse("[comm]\ncompression = \"int8\"\nint8_block = 256\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.compression_kind().unwrap(), Compression::Int8 { block: 256 });
        assert_eq!(
            ExperimentConfig::default().compression_kind().unwrap(),
            Compression::None
        );
        // Out-of-range knobs are rejected at validate time.
        let doc = Toml::parse("[comm]\ncompression = \"topk\"\ntopk_frac = 2.0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[comm]\ncompression = \"int8\"\nint8_block = 0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn cost_overrides_parse_and_resolve() {
        let doc = Toml::parse(
            "[cluster]\nnodes = 3\n[cost]\nalpha = 2e-3\ntheta = [1e-9, 2e-9, 3e-9]\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.cost_alpha, vec![2e-3]);
        assert_eq!(cfg.cost_theta.len(), 3);
        let base = CostModel::generic();
        let costs = cfg.node_costs(base).unwrap().expect("overrides present");
        assert_eq!(costs.alpha, vec![2e-3; 3], "scalar spreads to every node");
        assert_eq!(costs.theta, vec![1e-9, 2e-9, 3e-9]);
        assert_eq!(costs.compute, vec![base.compute; 3], "untouched component keeps the base");
        // No overrides at all => None (the bit-exact homogeneous path).
        let plain = ExperimentConfig::default();
        assert!(plain.node_costs(base).unwrap().is_none());
    }

    #[test]
    fn cost_overrides_reject_nonfinite_nonpositive_and_ragged() {
        // The NaN/negative-sim-clock guard: same bail! treatment
        // period/H_init/threads = 0 get.
        for bad in ["0.0", "-1e-3", "nan", "inf"] {
            let doc = Toml::parse(&format!("[cost]\nalpha = {bad}\n")).unwrap();
            assert!(ExperimentConfig::from_toml(&doc).is_err(), "alpha = {bad} must be rejected");
            let doc = Toml::parse(&format!("[cost]\ntheta = [{bad}]\n")).unwrap();
            assert!(ExperimentConfig::from_toml(&doc).is_err(), "theta = [{bad}]");
            let doc = Toml::parse(&format!("[cost]\ncompute = {bad}\n")).unwrap();
            assert!(ExperimentConfig::from_toml(&doc).is_err(), "compute = {bad}");
        }
        // Length must be 1 or n.
        let doc =
            Toml::parse("[cluster]\nnodes = 4\n[cost]\ncompute = [0.1, 0.2]\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn straggler_specs_parse_and_validate() {
        assert_eq!(parse_stragglers("").unwrap(), vec![]);
        assert_eq!(parse_stragglers("3:4").unwrap(), vec![(3, 4.0)]);
        assert_eq!(
            parse_stragglers("1:2.5, 6:8").unwrap(),
            vec![(1, 2.5), (6, 8.0)]
        );
        assert!(parse_stragglers("3").is_err());
        assert!(parse_stragglers("x:2").is_err());
        assert!(parse_stragglers("1:fast").is_err());

        let doc = Toml::parse(
            "[cluster]\nnodes = 8\n[cost]\nstraggler = \"3:4\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.stragglers, vec![(3, 4.0)]);
        let base = CostModel::calibrated_resnet50();
        let costs = cfg.node_costs(base).unwrap().unwrap();
        assert_eq!(costs.compute[3], 4.0 * base.compute);
        assert_eq!(costs.alpha[3], 4.0 * base.alpha);
        assert_eq!(costs.theta[3], base.theta);
        assert_eq!(costs.compute[0], base.compute);
        // Out-of-range index and non-positive factor are config errors.
        let doc = Toml::parse("[cluster]\nnodes = 4\n[cost]\nstraggler = \"4:2\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[cost]\nstraggler = \"0:0\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn regime_and_staleness_parse_from_toml() {
        // Explicit regimes.
        for (name, want) in
            [("bsp", Regime::Bsp), ("overlap", Regime::Overlap), ("async", Regime::Async)]
        {
            let doc = Toml::parse(&format!("[train]\nregime = \"{name}\"\n")).unwrap();
            let cfg = ExperimentConfig::from_toml(&doc).unwrap();
            assert_eq!(cfg.regime_kind().unwrap(), want);
        }
        assert_eq!(ExperimentConfig::default().regime_kind().unwrap(), Regime::Bsp);
        // Back-compat: bare train.overlap selects the overlap regime.
        let doc = Toml::parse("[train]\noverlap = true\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.regime_kind().unwrap(), Regime::Overlap);
        // Conflicting knobs are rejected, as is a staleness bound outside
        // the async regime.
        let doc = Toml::parse("[train]\noverlap = true\nregime = \"bsp\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[train]\nmax_staleness = 2\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[train]\nregime = \"async\"\nmax_staleness = 2\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.max_staleness, 2);
        assert_eq!(cfg.regime_kind().unwrap(), Regime::Async);
        // Strict async (max_staleness = 0) is the BSP-bit-exact anchor.
        let doc = Toml::parse("[train]\nregime = \"async\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().max_staleness, 0);
        let doc = Toml::parse("[train]\nregime = \"warp\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn duplicate_straggler_indices_are_rejected() {
        // `--straggler 0:4,3:2` is the multi-straggler form; `0:4,0:2`
        // used to silently compound to 8x on node 0.
        assert_eq!(parse_stragglers("0:4,3:2").unwrap(), vec![(0, 4.0), (3, 2.0)]);
        assert!(parse_stragglers("0:4,0:2").is_err());
        assert!(parse_stragglers("1:2, 1:2").is_err());
        let doc = Toml::parse("[cost]\nstraggler = \"2:4,2:8\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn sweep_config_defaults_valid_and_straggler_range_enforced() {
        SweepConfig::default().validate().unwrap();
        // The sweep-path range check (--straggler vs --virtual-n): the
        // train path has had its own since PR 4; this is the new one.
        let mut cfg = SweepConfig { virtual_n: 100, ..SweepConfig::default() };
        cfg.stragglers = vec![(99, 4.0)];
        cfg.validate().unwrap();
        cfg.stragglers = vec![(100, 4.0)];
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("--straggler index 100 out of range"), "{err}");
        assert!(err.contains("--virtual-n 100"), "{err}");
        cfg.stragglers = vec![(3, -1.0)];
        assert!(cfg.validate().is_err(), "non-positive factor");
    }

    #[test]
    fn sweep_config_rejects_conflicts_and_parses_regions() {
        let mut cfg = SweepConfig::default();
        cfg.surrogate = true;
        cfg.dim = 16;
        assert!(cfg.validate().unwrap_err().to_string().contains("--surrogate conflicts"));
        let mut cfg = SweepConfig::default();
        cfg.churn_pairs = 4;
        assert!(cfg.validate().is_err(), "seeded churn needs a horizon");
        cfg.churn_horizon = 10.0;
        cfg.validate().unwrap();
        let mut cfg = SweepConfig::default();
        cfg.regions = "4:10".into();
        assert_eq!(cfg.region_spec().unwrap(), Some((4, 10.0)));
        cfg.validate().unwrap();
        cfg.regions = "4".into();
        assert!(cfg.validate().is_err());
        cfg.regions = "0:10".into();
        assert!(cfg.validate().is_err());
        cfg.regions = "4:nan".into();
        assert!(cfg.validate().is_err());
        cfg.regions = String::new();
        assert_eq!(cfg.region_spec().unwrap(), None);
    }

    #[test]
    fn tcp_transport_keys_parse_and_validate() {
        let doc = Toml::parse(
            "[comm]\nbackend = \"tcp\"\nlisten = \"127.0.0.1:0\"\nround_timeout = 2.5\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.backend_kind().unwrap(), BackendKind::Tcp);
        assert_eq!(cfg.listen, "127.0.0.1:0");
        assert!((cfg.round_timeout - 2.5).abs() < 1e-12);
        // Defaults: loopback OS-assigned port, machine off, no peers.
        let d = ExperimentConfig::default();
        assert_eq!(d.listen, "127.0.0.1:0");
        assert_eq!(d.round_timeout, 0.0);
        assert!(d.peers.is_empty());
        // A multi-process mesh is rejected with a clear message, not a hang.
        let doc = Toml::parse(
            "[comm]\nbackend = \"tcp\"\npeers = [\"10.0.0.2:7000\", \"10.0.0.3:7000\"]\n",
        )
        .unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("not yet supported"), "{err}");
        // A bind address without a port is a config error.
        let doc = Toml::parse("[comm]\nbackend = \"tcp\"\nlisten = \"localhost\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        // The deadline needs a wire: shared + round_timeout is rejected...
        let doc = Toml::parse("[comm]\nround_timeout = 1.0\n").unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("deadline-capable"), "{err}");
        // ...and a negative budget is nonsense on any backend.
        let doc = Toml::parse("[comm]\nbackend = \"bus\"\nround_timeout = -1.0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = Toml::parse("[comm]\nbackend = \"bus\"\nround_timeout = 0.05\n").unwrap();
        ExperimentConfig::from_toml(&doc).unwrap();
    }

    #[test]
    fn stealing_parse_from_toml() {
        let doc = Toml::parse("[train]\nstealing = true\nthreads = 4\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(cfg.stealing);
        assert!(!ExperimentConfig::default().stealing, "static sharding is the default");
        let doc = Toml::parse("[train]\nstealing = 2\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "stealing must be a bool");
    }

    #[test]
    fn overlap_parse_from_toml() {
        let doc = Toml::parse("[train]\noverlap = true\nthreads = 4\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(cfg.overlap);
        // default is BSP, and overlap composes with threads = 1 (it
        // degenerates to the synchronous schedule).
        assert!(!ExperimentConfig::default().overlap);
        let doc = Toml::parse("[train]\noverlap = true\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).unwrap().overlap);
        let doc = Toml::parse("[train]\noverlap = 3\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "overlap must be a bool");
    }

    #[test]
    fn pin_parse_and_core_bound() {
        let doc = Toml::parse("[train]\npin = true\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(cfg.pin);
        assert!(!ExperimentConfig::default().pin, "unpinned is the default");
        let doc = Toml::parse("[train]\npin = 1\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "pin must be a bool");
        // Pinning more threads than cores would stack them — rejected with
        // a clear message, not a panic.
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let doc =
            Toml::parse(&format!("[train]\npin = true\nthreads = {}\n", cores + 1)).unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("available cores"), "{err}");
        // Without pin the same thread count is fine (oversubscription is
        // allowed when the OS can still migrate threads).
        let doc = Toml::parse(&format!("[train]\nthreads = {}\n", cores + 1)).unwrap();
        ExperimentConfig::from_toml(&doc).unwrap();
    }

    #[test]
    fn trace_keys_parse_and_validate() {
        let doc = Toml::parse("[trace]\npath = \"out.json\"\ncapacity = 128\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.trace_path, "out.json");
        assert_eq!(cfg.trace_capacity, 128);
        // Defaults: tracing off, a generous ring.
        let d = ExperimentConfig::default();
        assert_eq!(d.trace_path, "");
        assert_eq!(d.trace_capacity, 65536);
        // A zero-capacity ring can hold no span — rejected with a clear
        // message, not a mysteriously empty trace.
        let doc = Toml::parse("[trace]\ncapacity = 0\n").unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("trace.capacity must be >= 1"), "{err}");
        // Type errors surface as such.
        let doc = Toml::parse("[trace]\npath = 7\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "path must be a string");
        let doc = Toml::parse("[trace]\ncapacity = \"big\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err(), "capacity must be an integer");
    }

    #[test]
    fn pipeline_depth_parse_and_validate() {
        let doc = Toml::parse("[train]\npipeline_depth = 4\nregime = \"overlap\"\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.pipeline_depth, 4);
        assert_eq!(
            ExperimentConfig::default().pipeline_depth,
            1,
            "the classic double buffer is the default"
        );
        // Depth 0 has no scratch slot to mix into — rejected.
        let doc = Toml::parse("[train]\npipeline_depth = 0\n").unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("pipeline_depth"), "{err}");
        // The async event plane schedules its own in-flight rounds.
        let doc =
            Toml::parse("[train]\npipeline_depth = 2\nregime = \"async\"\n").unwrap();
        let err = ExperimentConfig::from_toml(&doc).unwrap_err().to_string();
        assert!(err.contains("async"), "{err}");
        // Depth 1 composes with every regime (it IS today's behavior).
        let doc = Toml::parse("[train]\npipeline_depth = 1\nregime = \"async\"\n").unwrap();
        ExperimentConfig::from_toml(&doc).unwrap();
        // Depth > 1 under plain BSP is allowed: the ring only engages when
        // rounds are actually issued asynchronously.
        let doc = Toml::parse("[train]\npipeline_depth = 2\n").unwrap();
        ExperimentConfig::from_toml(&doc).unwrap();
    }
}
