//! Minimal JSON substrate (parser + writer).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`) and for
//! machine-readable metrics dumps. Supports the full JSON value model with
//! the usual escapes; numbers are f64 (the manifest only contains small
//! integers and strings).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("field '{key}' not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("field '{key}' not a number"))
    }

    /// Serialize (stable key order — Obj is a BTreeMap).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for metric dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num_arr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

/// Counter arrays (traffic accounting). Exact for values < 2^53 — far
/// beyond any run's scalar counts; dumped as integers.
pub fn u64_arr(v: &[u64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 from the byte stream.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        // Multi-byte: find the full char.
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|_| anyhow!("bad number '{text}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"Aβ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"Aβ");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn manifest_shape_smoke() {
        let m = r#"{"version":1,"artifacts":[{"name":"x","flat_dim":10,
            "inputs":[{"name":"w","shape":[10],"dtype":"f32"}]}]}"#;
        let v = Json::parse(m).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 1);
        let arts = v.req("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].req_usize("flat_dim").unwrap(), 10);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = crate::artifacts_dir().join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.req("artifacts").unwrap().as_arr().unwrap().len() >= 5);
        }
    }
}
