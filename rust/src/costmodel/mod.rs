//! The paper's alpha-beta communication time model (§3.4, Appendix D/H).
//!
//! `alpha` = point-to-point latency, `theta` = per-scalar transfer time.
//! For a d-dimensional model:
//!
//! * All-Reduce global average: `2 theta d + n alpha`           (§3.4)
//! * one gossip round:          `|N_i| theta d + alpha`          (§3.4)
//! * Gossip-PGA amortized:      gossip + all-reduce / H
//! * Local SGD amortized:       all-reduce / H
//!
//! Constants are calibrated from the paper's own measurements (Appendix H,
//! Table 17): ResNet-50 (d = 25.5 M): all-reduce 278 ms, gossip 150 ms on a
//! one-peer graph (|N_i| = 2 incl. self), n = 32 nodes.

use crate::topology::Topology;

/// alpha-beta link model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Point-to-point latency (seconds).
    pub alpha: f64,
    /// Transfer time per f32 scalar (seconds).
    pub theta: f64,
    /// Per-iteration compute time (seconds) — added to every algorithm
    /// uniformly ("both have the same computational overhead per iteration").
    pub compute: f64,
}

impl CostModel {
    /// Calibrated against the paper's Table 17 ResNet-50 row (25 Gbps TCP):
    /// gossip (one-peer, 2 transfers of d) ~ 150 ms, all-reduce ~ 278 ms,
    /// compute 146 ms, n = 32, d = 25.5e6.
    ///
    /// gossip = 2 theta d + alpha       => theta ~ 150e-3 / (2 * 25.5e6)
    /// allreduce = 2 theta d + n alpha  => alpha ~ (278 - 150) ms / 32
    pub fn calibrated_resnet50() -> Self {
        let d = 25.5e6;
        let theta = 150e-3 / (2.0 * d);
        let alpha = (278e-3 - 2.0 * theta * d) / 32.0;
        CostModel { alpha, theta, compute: 146e-3 }
    }

    /// Calibrated against the BERT-Large row: gossip 566.5 ms,
    /// all-reduce 1468.8 ms, compute 445 ms, d = 330e6, n = 8.
    pub fn calibrated_bert() -> Self {
        let d = 330e6;
        let theta = 566.5e-3 / (2.0 * d);
        let alpha = (1468.8e-3 - 2.0 * theta * d) / 8.0;
        CostModel { alpha, theta, compute: 445e-3 }
    }

    /// A generic datacenter-ish model for analytic tables.
    pub fn generic() -> Self {
        CostModel { alpha: 1e-4, theta: 3e-9, compute: 0.0 }
    }

    /// All-Reduce time for a d-dimensional model over n nodes: 2 theta d + n alpha.
    pub fn all_reduce(&self, n: usize, d: usize) -> f64 {
        2.0 * self.theta * d as f64 + n as f64 * self.alpha
    }

    /// One gossip round: |N_i| theta d + alpha, with |N_i| the max
    /// neighborhood size (paper counts self in |N_i|; the self "transfer"
    /// is free, so we count transfers = |N_i| - 1 ... the paper's §3.4
    /// formula uses |N_i| directly; we follow the paper).
    pub fn gossip(&self, topo: &Topology, d: usize) -> f64 {
        topo.max_degree_incl_self() as f64 * self.theta * d as f64 + self.alpha
    }

    /// Per-iteration communication time of each algorithm (amortized).
    pub fn per_iter(&self, algo: AlgoCost, topo: &Topology, d: usize, h: usize) -> f64 {
        let n = topo.n;
        match algo {
            AlgoCost::Parallel => self.all_reduce(n, d),
            AlgoCost::Gossip => self.gossip(topo, d),
            AlgoCost::Local => self.all_reduce(n, d) / h as f64,
            AlgoCost::GossipPga => self.gossip(topo, d) + self.all_reduce(n, d) / h as f64,
        }
    }

    /// Wall-clock time for `iters` iterations including compute.
    pub fn total_time(&self, algo: AlgoCost, topo: &Topology, d: usize, h: usize, iters: usize) -> f64 {
        iters as f64 * (self.compute + self.per_iter(algo, topo, d, h))
    }
}

/// Communication pattern classes the model distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoCost {
    Parallel,
    Gossip,
    Local,
    GossipPga,
}

/// Transient *time* = transient iterations x per-iteration comm time —
/// the quantity of Tables 5 and 12–14.
pub fn transient_time(
    model: &CostModel,
    algo: AlgoCost,
    topo: &Topology,
    d: usize,
    h: usize,
    transient_iters: f64,
) -> f64 {
    transient_iters * (model.compute + model.per_iter(algo, topo, d, h))
}

/// A simulated clock that the coordinator advances as it executes; lets a
/// single-process run report paper-style wall-clock columns.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub seconds: f64,
}

impl SimClock {
    pub fn advance(&mut self, dt: f64) {
        self.seconds += dt;
    }
    pub fn hours(&self) -> f64 {
        self.seconds / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_table17_resnet() {
        let m = CostModel::calibrated_resnet50();
        let d = 25_500_000;
        let ar = m.all_reduce(32, d);
        assert!((ar - 0.278).abs() < 1e-3, "all-reduce {ar}");
        // One-peer gossip (degree incl self = 2).
        let topo = Topology::one_peer_expo(32);
        let g = m.gossip(&topo, d);
        assert!((g - 0.150).abs() < 5e-3, "gossip {g}");
    }

    #[test]
    fn calibration_reproduces_table17_bert() {
        let m = CostModel::calibrated_bert();
        let ar = m.all_reduce(8, 330_000_000);
        assert!((ar - 1.4688).abs() < 1e-2, "all-reduce {ar}");
    }

    #[test]
    fn gossip_cheaper_than_allreduce_at_scale() {
        // The paper's premise (Table 17): one-peer gossip < all-reduce at
        // scale — the n*alpha latency term dominates. (On a ring, gossip
        // moves 3 theta d vs all-reduce's 2 theta d, so the advantage is
        // specifically a latency advantage; the paper's clusters use the
        // one-peer exponential graph for deep runs.)
        let m = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(64);
        let d = 25_000_000;
        assert!(m.gossip(&topo, d) < m.all_reduce(64, d));
    }

    #[test]
    fn pga_amortization_shrinks_with_h() {
        let m = CostModel::generic();
        let topo = Topology::ring(32);
        let d = 1_000_000;
        let t_h4 = m.per_iter(AlgoCost::GossipPga, &topo, d, 4);
        let t_h48 = m.per_iter(AlgoCost::GossipPga, &topo, d, 48);
        assert!(t_h48 < t_h4);
        // And PGA(H) is bounded below by plain gossip.
        assert!(t_h48 > m.per_iter(AlgoCost::Gossip, &topo, d, 1));
    }

    #[test]
    fn pga_per_iter_cheaper_than_parallel() {
        // For H >= 2 and reasonable n, PGA's amortized comm < all-reduce.
        let m = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(32);
        let d = 25_500_000;
        let pga = m.per_iter(AlgoCost::GossipPga, &topo, d, 6);
        let par = m.per_iter(AlgoCost::Parallel, &topo, d, 1);
        assert!(pga < par, "pga {pga} vs parallel {par}");
    }

    #[test]
    fn sim_clock_accumulates() {
        let mut c = SimClock::default();
        c.advance(1800.0);
        c.advance(1800.0);
        assert!((c.hours() - 1.0).abs() < 1e-12);
    }
}
