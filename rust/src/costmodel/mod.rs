//! The paper's alpha-beta communication time model (§3.4, Appendix D/H),
//! and the per-node virtual-time plane built on top of it.
//!
//! `alpha` = point-to-point latency, `theta` = per-scalar transfer time.
//! For a d-dimensional model:
//!
//! * All-Reduce global average: `2 theta d + n alpha`           (§3.4)
//! * one gossip round:          `|N_i| theta d + alpha`          (§3.4)
//! * Gossip-PGA amortized:      gossip + all-reduce / H
//! * Local SGD amortized:       all-reduce / H
//!
//! Constants are calibrated from the paper's own measurements (Appendix H,
//! Table 17): ResNet-50 (d = 25.5 M): all-reduce 278 ms, gossip 150 ms on a
//! one-peer graph (|N_i| = 2 incl. self), n = 32 nodes.
//!
//! §Virtual time. [`CostModel`] is a *scalar* model: one alpha/theta/compute
//! triple shared by every node, which can only describe a homogeneous
//! cluster advancing in lockstep. [`NodeCosts`] generalizes it to a
//! per-node table (heterogeneous clusters, stragglers, per-link asymmetry)
//! and [`VirtualClocks`] carries one simulated clock per node, advanced per
//! action under the action's [`BarrierScope`]:
//!
//! * local compute: node i advances by its own `compute[i]`;
//! * a gossip round synchronizes each node with its **in-neighborhood**
//!   only, so a straggler's slowness propagates one hop per round instead
//!   of stalling the whole cluster;
//! * a global average (and eval / checkpoint) is a **full barrier**: every
//!   node waits for the slowest.
//!
//! The billing convention is "a node cannot begin iteration k until every
//! peer it will hear from has finished iteration k-1"; each step then costs
//! the node one fused `compute + comm` charge. With a homogeneous cost
//! table the critical path (`max_seconds`, the reported `sim_seconds`)
//! reproduces the pre-refactor scalar [`SimClock`] **bit-exactly** — the
//! scalar clock always billed each action's busiest node, and that node's
//! barrier start is its own clock (same additions, same order; asserted by
//! `rust/tests/virtual_time.rs`) — so every existing time table is
//! unchanged while the straggler scenario space opens up. Whether the
//! *other* clocks stay in lockstep depends on per-node traffic too: on
//! regular topologies with even bus chunks they do (slack and waits stay
//! 0); a homogeneous star still spreads, because its leaves genuinely wait
//! on the busier hub — structural asymmetry the scalar clock could never
//! show.

use anyhow::{bail, Result};

use crate::topology::Topology;

/// alpha-beta link model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Point-to-point latency (seconds).
    pub alpha: f64,
    /// Transfer time per f32 scalar (seconds).
    pub theta: f64,
    /// Per-iteration compute time (seconds) — added to every algorithm
    /// uniformly ("both have the same computational overhead per iteration").
    pub compute: f64,
}

impl CostModel {
    /// Calibrated against the paper's Table 17 ResNet-50 row (25 Gbps TCP):
    /// gossip (one-peer, 2 transfers of d) ~ 150 ms, all-reduce ~ 278 ms,
    /// compute 146 ms, n = 32, d = 25.5e6.
    ///
    /// gossip = 2 theta d + alpha       => theta ~ 150e-3 / (2 * 25.5e6)
    /// allreduce = 2 theta d + n alpha  => alpha ~ (278 - 150) ms / 32
    pub fn calibrated_resnet50() -> Self {
        let d = 25.5e6;
        let theta = 150e-3 / (2.0 * d);
        let alpha = (278e-3 - 2.0 * theta * d) / 32.0;
        CostModel { alpha, theta, compute: 146e-3 }
    }

    /// Calibrated against the BERT-Large row: gossip 566.5 ms,
    /// all-reduce 1468.8 ms, compute 445 ms, d = 330e6, n = 8.
    pub fn calibrated_bert() -> Self {
        let d = 330e6;
        let theta = 566.5e-3 / (2.0 * d);
        let alpha = (1468.8e-3 - 2.0 * theta * d) / 8.0;
        CostModel { alpha, theta, compute: 445e-3 }
    }

    /// A generic datacenter-ish model for analytic tables.
    pub fn generic() -> Self {
        CostModel { alpha: 1e-4, theta: 3e-9, compute: 0.0 }
    }

    /// All-Reduce time for a d-dimensional model over n nodes: 2 theta d + n alpha.
    pub fn all_reduce(&self, n: usize, d: usize) -> f64 {
        2.0 * self.theta * d as f64 + n as f64 * self.alpha
    }

    /// One gossip round: |N_i| theta d + alpha, with |N_i| the max
    /// neighborhood size (paper counts self in |N_i|; the self "transfer"
    /// is free, so we count transfers = |N_i| - 1 ... the paper's §3.4
    /// formula uses |N_i| directly; we follow the paper).
    pub fn gossip(&self, topo: &Topology, d: usize) -> f64 {
        topo.max_degree_incl_self() as f64 * self.theta * d as f64 + self.alpha
    }

    /// Per-iteration communication time of each algorithm (amortized).
    pub fn per_iter(&self, algo: AlgoCost, topo: &Topology, d: usize, h: usize) -> f64 {
        let n = topo.n;
        match algo {
            AlgoCost::Parallel => self.all_reduce(n, d),
            AlgoCost::Gossip => self.gossip(topo, d),
            AlgoCost::Local => self.all_reduce(n, d) / h as f64,
            AlgoCost::GossipPga => self.gossip(topo, d) + self.all_reduce(n, d) / h as f64,
        }
    }

    /// Wall-clock time for `iters` iterations including compute.
    pub fn total_time(&self, algo: AlgoCost, topo: &Topology, d: usize, h: usize, iters: usize) -> f64 {
        iters as f64 * (self.compute + self.per_iter(algo, topo, d, h))
    }
}

/// Communication pattern classes the model distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoCost {
    Parallel,
    Gossip,
    Local,
    GossipPga,
}

/// Transient *time* = transient iterations x per-iteration comm time —
/// the quantity of Tables 5 and 12–14.
pub fn transient_time(
    model: &CostModel,
    algo: AlgoCost,
    topo: &Topology,
    d: usize,
    h: usize,
    transient_iters: f64,
) -> f64 {
    transient_iters * (model.compute + model.per_iter(algo, topo, d, h))
}

/// A simulated clock that the coordinator advances as it executes; lets a
/// single-process run report paper-style wall-clock columns.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub seconds: f64,
}

impl SimClock {
    pub fn advance(&mut self, dt: f64) {
        self.seconds += dt;
    }
    pub fn hours(&self) -> f64 {
        self.seconds / 3600.0
    }
}

/// Per-node alpha-beta model: node i's point-to-point latency, per-scalar
/// transfer time and per-iteration compute time. The scalar [`CostModel`]
/// is the homogeneous special case ([`NodeCosts::homogeneous`]); per-node
/// overrides come from the `[cost]` config section (`cost.alpha`,
/// `cost.theta`, `cost.compute` — scalar or length-n array) and the
/// `--straggler idx:factor` convenience flag.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeCosts {
    /// Per-node point-to-point latency (seconds).
    pub alpha: Vec<f64>,
    /// Per-node transfer time per f32 scalar (seconds).
    pub theta: Vec<f64>,
    /// Per-node per-iteration compute time (seconds).
    pub compute: Vec<f64>,
}

impl NodeCosts {
    /// Every node carries the scalar model's costs — the lockstep case the
    /// pre-virtual-time clock described.
    pub fn homogeneous(base: CostModel, n: usize) -> NodeCosts {
        NodeCosts {
            alpha: vec![base.alpha; n],
            theta: vec![base.theta; n],
            compute: vec![base.compute; n],
        }
    }

    pub fn n(&self) -> usize {
        self.alpha.len()
    }

    /// True when every node carries identical costs (clocks stay lockstep
    /// and the barriers are no-ops).
    pub fn is_homogeneous(&self) -> bool {
        let same = |v: &[f64]| v.windows(2).all(|w| w[0] == w[1]);
        same(&self.alpha) && same(&self.theta) && same(&self.compute)
    }

    /// Mark node `idx` as a straggler: its compute AND its per-message
    /// latency `alpha` scale by `factor` (an overloaded node computes
    /// slowly and is slow to service transfers; wire bandwidth `theta` is a
    /// link/NIC property and stays — override `cost.theta` directly for
    /// bandwidth asymmetry). This is the §3.4 story under heterogeneity:
    /// All-Reduce pays the straggler's latency n times per round, one-peer
    /// gossip pays it once.
    pub fn with_straggler(mut self, idx: usize, factor: f64) -> Result<NodeCosts> {
        let n = self.n();
        if idx >= n {
            bail!("straggler index {idx} out of range for {n} nodes");
        }
        if !(factor.is_finite() && factor > 0.0) {
            bail!("straggler factor must be finite and positive, got {factor}");
        }
        self.compute[idx] *= factor;
        self.alpha[idx] *= factor;
        Ok(self)
    }

    /// Reject tables a simulated clock cannot bill: every entry must be
    /// finite, `alpha`/`theta` positive, `compute` non-negative (analytic
    /// tables legitimately bill pure communication).
    pub fn validate(&self) -> Result<()> {
        let n = self.n();
        if n == 0 || self.theta.len() != n || self.compute.len() != n {
            bail!(
                "cost table shape mismatch: {} alpha / {} theta / {} compute entries",
                self.alpha.len(),
                self.theta.len(),
                self.compute.len()
            );
        }
        for (name, v, min_excl) in [
            ("alpha", &self.alpha, true),
            ("theta", &self.theta, true),
            ("compute", &self.compute, false),
        ] {
            for (i, x) in v.iter().enumerate() {
                if !x.is_finite() || (min_excl && *x <= 0.0) || *x < 0.0 {
                    let want = if min_excl { "positive" } else { "non-negative" };
                    bail!("cost.{name}[{i}] must be finite and {want}, got {x}");
                }
            }
        }
        Ok(())
    }

    /// Node i's cost of one gossip round at in-degree `deg_incl_self`:
    /// `|N_i| theta_i d + alpha_i` (§3.4, billed at the node's own
    /// neighborhood size). Bit-identical to [`CostModel::gossip`] for the
    /// max-degree node of a homogeneous table.
    pub fn gossip_node(&self, i: usize, deg_incl_self: usize, d: usize) -> f64 {
        deg_incl_self as f64 * self.theta[i] * d as f64 + self.alpha[i]
    }

    /// Node i's cost of one exact global average over `n` nodes:
    /// `2 theta_i d + n alpha_i` (§3.4). Bit-identical to
    /// [`CostModel::all_reduce`] on a homogeneous table.
    pub fn all_reduce_node(&self, i: usize, n: usize, d: usize) -> f64 {
        2.0 * self.theta[i] * d as f64 + n as f64 * self.alpha[i]
    }

    /// Critical-path time of one gossip round: a single [`VirtualClocks`]
    /// advance from zero under the round's neighborhood barrier, maxed over
    /// the topology's round cycle. Equals [`CostModel::gossip`] bit-exactly
    /// on a homogeneous table.
    pub fn gossip_critical(&self, topo: &Topology, d: usize) -> f64 {
        let n = self.n();
        debug_assert_eq!(n, topo.n);
        let zeros = vec![0.0; n];
        let mut worst = 0.0f64;
        for r in 0..topo.rounds() {
            let comm: Vec<f64> = (0..n)
                .map(|i| self.gossip_node(i, topo.in_neighbors(i, r).len(), d))
                .collect();
            let mut clocks = VirtualClocks::new(topo);
            clocks.advance(&zeros, &comm, BarrierScope::Neighborhood { round: r });
            worst = worst.max(clocks.max_seconds());
        }
        worst
    }

    /// Critical-path time of one global average: a single full-barrier
    /// [`VirtualClocks`] advance from zero. Equals [`CostModel::all_reduce`]
    /// bit-exactly on a homogeneous table.
    pub fn all_reduce_critical(&self, topo: &Topology, d: usize) -> f64 {
        let n = self.n();
        debug_assert_eq!(n, topo.n);
        let zeros = vec![0.0; n];
        let comm: Vec<f64> = (0..n).map(|i| self.all_reduce_node(i, n, d)).collect();
        let mut clocks = VirtualClocks::new(topo);
        clocks.advance(&zeros, &comm, BarrierScope::Global);
        clocks.max_seconds()
    }
}

/// Per-region latency tiers for the virtual population plane: nodes are
/// assigned to k contiguous regions, and a directed transfer from node a
/// to node b multiplies its traversal time by `mult[region(a)][region(b)]`
/// — the "replicas in different datacenters" scenario (intra-region links
/// fast, inter-region links slow) that SGP/GossipGraD run on real
/// clusters. O(n + k^2) memory, O(1) lookup: safe at n = 10^5.
#[derive(Clone, Debug, PartialEq)]
pub struct RegionMap {
    /// Node -> region id (length n).
    region: Vec<u32>,
    /// Row-major k x k traversal multiplier table.
    mult: Vec<f64>,
    k: usize,
}

impl RegionMap {
    /// n nodes in k contiguous, near-equal blocks; links inside a region
    /// multiply traversal by `intra`, links across regions by `inter`.
    pub fn tiers(n: usize, k: usize, intra: f64, inter: f64) -> Result<RegionMap> {
        if k == 0 || k > n {
            bail!("region count {k} must be in 1..={n}");
        }
        for (name, f) in [("intra", intra), ("inter", inter)] {
            if !(f.is_finite() && f > 0.0) {
                bail!("{name}-region factor must be finite and positive, got {f}");
            }
        }
        let per = n.div_ceil(k);
        let region = (0..n).map(|i| (i / per) as u32).collect();
        let mut mult = vec![inter; k * k];
        for r in 0..k {
            mult[r * k + r] = intra;
        }
        Ok(RegionMap { region, mult, k })
    }

    /// Explicit assignment + multiplier table (row-major k x k).
    pub fn from_parts(region: Vec<u32>, mult: Vec<f64>, k: usize) -> Result<RegionMap> {
        if k == 0 || mult.len() != k * k {
            bail!("region multiplier table must be {k} x {k}, got {} entries", mult.len());
        }
        if let Some(bad) = region.iter().find(|&&r| r as usize >= k) {
            bail!("node assigned to region {bad}, table has {k} regions");
        }
        if let Some(bad) = mult.iter().find(|f| !(f.is_finite() && **f > 0.0)) {
            bail!("region multiplier must be finite and positive, got {bad}");
        }
        Ok(RegionMap { region, mult, k })
    }

    /// Nodes covered by the map.
    pub fn n(&self) -> usize {
        self.region.len()
    }

    pub fn regions(&self) -> usize {
        self.k
    }

    /// Node a's region id.
    pub fn region_of(&self, a: usize) -> usize {
        self.region[a] as usize
    }

    /// Traversal multiplier for a directed a -> b transfer.
    pub fn factor(&self, a: usize, b: usize) -> f64 {
        self.mult[self.region[a] as usize * self.k + self.region[b] as usize]
    }
}

/// Which nodes a clock advance synchronizes before it runs — the
/// [`VirtualClocks`] counterpart of a communication action's wait set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BarrierScope {
    /// No synchronization (local compute only).
    None,
    /// Each node waits for its in-neighborhood (incl. itself) at `round` —
    /// one gossip round's wait set; slowness propagates one hop per round.
    Neighborhood { round: usize },
    /// Full barrier: every node waits for the slowest (global average,
    /// eval, checkpoint).
    Global,
}

/// One simulated clock per node, advanced by the coordinator as it
/// executes; `max_seconds` is the run's critical path (what the paper's
/// wall-clock columns measure on a heterogeneous cluster), `slack` the
/// fastest-to-slowest spread, and `waited` the per-node time lost stalled
/// at barriers behind slower peers.
///
/// Determinism/compatibility contract: each advance charges node i a single
/// fused `start_i + (compute_i + comm_i)` addition, where `start_i` is the
/// barrier max over the scope (an exact f64 max, no rounding). When every
/// node's charge is identical (homogeneous costs, uniform traffic) every
/// `start_i` equals the node's own clock and the accumulation is literally
/// the scalar [`SimClock`]'s `seconds += compute + comm` sequence; more
/// generally the action's busiest node has its own clock as its barrier
/// start, so `max_seconds` tracks the scalar bill bit-exactly on either
/// backend even when degrees or chunk sizes differ across nodes.
#[derive(Clone, Debug)]
pub struct VirtualClocks {
    seconds: Vec<f64>,
    waited: Vec<f64>,
    /// In-neighbors incl. self per round — the wait set of one gossip round
    /// (same tables the mixer's weight rows index).
    neigh: Vec<Vec<Vec<usize>>>,
    /// Scratch for barrier starts (no per-step allocation).
    starts: Vec<f64>,
}

impl VirtualClocks {
    pub fn new(topo: &Topology) -> VirtualClocks {
        let n = topo.n;
        let neigh = (0..topo.rounds())
            .map(|r| (0..n).map(|i| topo.in_neighbors(i, r)).collect())
            .collect();
        VirtualClocks {
            seconds: vec![0.0; n],
            waited: vec![0.0; n],
            neigh,
            starts: vec![0.0; n],
        }
    }

    /// A clock plane with NO neighborhood tables — for billing paths that
    /// only use [`VirtualClocks::advance_one`] / [`VirtualClocks::stall_until`]
    /// (plus `Global`/`None` scopes). The per-round in-neighbor tables that
    /// [`VirtualClocks::new`] precomputes cost O(n * rounds * degree)
    /// memory, which at n = 10^5 on one-peer-expo is the largest allocation
    /// in a sweep; the population plane bills per event and never takes a
    /// `Neighborhood` barrier, so it skips them. Calling `advance` with
    /// `BarrierScope::Neighborhood` on a flat plane panics (empty table).
    pub fn flat(n: usize) -> VirtualClocks {
        VirtualClocks {
            seconds: vec![0.0; n],
            waited: vec![0.0; n],
            neigh: Vec::new(),
            starts: vec![0.0; n],
        }
    }

    pub fn n(&self) -> usize {
        self.seconds.len()
    }

    /// Per-node clock readings (seconds of virtual time consumed).
    pub fn seconds(&self) -> &[f64] {
        &self.seconds
    }

    /// Per-node cumulative barrier-wait seconds (time stalled behind
    /// slower peers).
    pub fn waited(&self) -> &[f64] {
        &self.waited
    }

    /// The critical path: the slowest node's clock (== every node's clock
    /// in a homogeneous run — the pre-refactor `sim_seconds`).
    pub fn max_seconds(&self) -> f64 {
        self.seconds.iter().copied().fold(0.0, f64::max)
    }

    /// The fastest node's clock.
    pub fn min_seconds(&self) -> f64 {
        self.seconds.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Straggler slack: critical path minus the fastest node (0 in a
    /// homogeneous run).
    pub fn slack(&self) -> f64 {
        self.max_seconds() - self.min_seconds()
    }

    /// Total barrier-wait seconds summed over nodes.
    pub fn total_wait(&self) -> f64 {
        self.waited.iter().sum()
    }

    /// Advance every node by one action: `clock_i <- start_i +
    /// (compute_i + comm_i)` with `start_i` the barrier max over `scope`
    /// (see the struct docs for the exactness contract). `start_i -
    /// clock_i` accrues into the node's barrier-wait account.
    pub fn advance(&mut self, compute: &[f64], comm: &[f64], scope: BarrierScope) {
        let n = self.seconds.len();
        debug_assert!(compute.len() == n && comm.len() == n);
        match scope {
            BarrierScope::None => {
                for i in 0..n {
                    self.seconds[i] += compute[i] + comm[i];
                }
            }
            BarrierScope::Global => {
                let start = self.max_seconds();
                for i in 0..n {
                    self.waited[i] += start - self.seconds[i];
                    self.seconds[i] = start + (compute[i] + comm[i]);
                }
            }
            BarrierScope::Neighborhood { round } => {
                let tbl = &self.neigh[round % self.neigh.len()];
                for i in 0..n {
                    self.starts[i] = tbl[i]
                        .iter()
                        .map(|&j| self.seconds[j])
                        .fold(f64::NEG_INFINITY, f64::max);
                }
                for i in 0..n {
                    self.waited[i] += self.starts[i] - self.seconds[i];
                    self.seconds[i] = self.starts[i] + (compute[i] + comm[i]);
                }
            }
        }
    }

    /// Event-queue advancement (the [`crate::eventsim`] plane): charge a
    /// single node `dt` seconds on its own clock, no barrier. The per-link
    /// discrete-event engine bills compute and send-initiation charges
    /// through this, reserving [`VirtualClocks::advance`]'s barrier scopes
    /// for the collectives that really synchronize.
    pub fn advance_one(&mut self, i: usize, dt: f64) {
        self.seconds[i] += dt;
    }

    /// Event-queue stall: node `i` blocks until virtual time `t` (a
    /// violated staleness bound waiting on a delivery); the blocked span
    /// accrues to its barrier-wait account. No-op when the node's clock is
    /// already past `t`.
    pub fn stall_until(&mut self, i: usize, t: f64) {
        if t > self.seconds[i] {
            self.waited[i] += t - self.seconds[i];
            self.seconds[i] = t;
        }
    }

    /// Full synchronization point with no cost of its own (eval,
    /// checkpoint): everyone advances to the barrier max, the difference
    /// accruing as barrier wait. A no-op while the clocks agree.
    pub fn sync(&mut self) {
        let start = self.max_seconds();
        for i in 0..self.seconds.len() {
            self.waited[i] += start - self.seconds[i];
            self.seconds[i] = start;
        }
    }

    /// Overwrite the full state (checkpoint v4 restore).
    pub fn restore(&mut self, seconds: &[f64], waited: &[f64]) -> Result<()> {
        let n = self.seconds.len();
        if seconds.len() != n || waited.len() != n {
            bail!(
                "checkpoint carries {} clocks / {} waits for {n} nodes",
                seconds.len(),
                waited.len()
            );
        }
        self.seconds.copy_from_slice(seconds);
        self.waited.copy_from_slice(waited);
        Ok(())
    }

    /// Restore from a pre-v4 checkpoint: one scalar clock, so every node
    /// resumes at it with zeroed wait accounts (the old time axis).
    pub fn restore_uniform(&mut self, seconds: f64) {
        self.seconds.fill(seconds);
        self.waited.fill(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_table17_resnet() {
        let m = CostModel::calibrated_resnet50();
        let d = 25_500_000;
        let ar = m.all_reduce(32, d);
        assert!((ar - 0.278).abs() < 1e-3, "all-reduce {ar}");
        // One-peer gossip (degree incl self = 2).
        let topo = Topology::one_peer_expo(32);
        let g = m.gossip(&topo, d);
        assert!((g - 0.150).abs() < 5e-3, "gossip {g}");
    }

    #[test]
    fn calibration_reproduces_table17_bert() {
        let m = CostModel::calibrated_bert();
        let ar = m.all_reduce(8, 330_000_000);
        assert!((ar - 1.4688).abs() < 1e-2, "all-reduce {ar}");
    }

    #[test]
    fn gossip_cheaper_than_allreduce_at_scale() {
        // The paper's premise (Table 17): one-peer gossip < all-reduce at
        // scale — the n*alpha latency term dominates. (On a ring, gossip
        // moves 3 theta d vs all-reduce's 2 theta d, so the advantage is
        // specifically a latency advantage; the paper's clusters use the
        // one-peer exponential graph for deep runs.)
        let m = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(64);
        let d = 25_000_000;
        assert!(m.gossip(&topo, d) < m.all_reduce(64, d));
    }

    #[test]
    fn pga_amortization_shrinks_with_h() {
        let m = CostModel::generic();
        let topo = Topology::ring(32);
        let d = 1_000_000;
        let t_h4 = m.per_iter(AlgoCost::GossipPga, &topo, d, 4);
        let t_h48 = m.per_iter(AlgoCost::GossipPga, &topo, d, 48);
        assert!(t_h48 < t_h4);
        // And PGA(H) is bounded below by plain gossip.
        assert!(t_h48 > m.per_iter(AlgoCost::Gossip, &topo, d, 1));
    }

    #[test]
    fn pga_per_iter_cheaper_than_parallel() {
        // For H >= 2 and reasonable n, PGA's amortized comm < all-reduce.
        let m = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(32);
        let d = 25_500_000;
        let pga = m.per_iter(AlgoCost::GossipPga, &topo, d, 6);
        let par = m.per_iter(AlgoCost::Parallel, &topo, d, 1);
        assert!(pga < par, "pga {pga} vs parallel {par}");
    }

    #[test]
    fn sim_clock_accumulates() {
        let mut c = SimClock::default();
        c.advance(1800.0);
        c.advance(1800.0);
        assert!((c.hours() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_node_costs_match_scalar_model_bitwise() {
        let base = CostModel::calibrated_resnet50();
        for topo in [Topology::ring(8), Topology::one_peer_expo(8), Topology::star(8)] {
            let costs = NodeCosts::homogeneous(base, topo.n);
            assert!(costs.is_homogeneous());
            let d = 1_000_000;
            assert_eq!(costs.gossip_critical(&topo, d), base.gossip(&topo, d), "{:?}", topo.kind);
            assert_eq!(
                costs.all_reduce_critical(&topo, d),
                base.all_reduce(topo.n, d),
                "{:?}",
                topo.kind
            );
        }
    }

    #[test]
    fn straggler_scales_compute_and_alpha_only() {
        let base = CostModel::generic();
        let costs = NodeCosts::homogeneous(base, 4).with_straggler(2, 4.0).unwrap();
        assert!(!costs.is_homogeneous());
        assert_eq!(costs.alpha[2], 4.0 * base.alpha);
        assert_eq!(costs.compute[2], 4.0 * base.compute);
        assert_eq!(costs.theta[2], base.theta, "theta is a link property, untouched");
        assert_eq!(costs.alpha[0], base.alpha);
        assert!(NodeCosts::homogeneous(base, 4).with_straggler(4, 2.0).is_err());
        assert!(NodeCosts::homogeneous(base, 4).with_straggler(0, 0.0).is_err());
        assert!(NodeCosts::homogeneous(base, 4).with_straggler(0, f64::NAN).is_err());
    }

    #[test]
    fn node_costs_validate_rejects_bad_entries() {
        let base = CostModel::calibrated_resnet50();
        NodeCosts::homogeneous(base, 3).validate().unwrap();
        // Zero compute is legal (pure-comm analytic tables)...
        NodeCosts::homogeneous(CostModel::generic(), 3).validate().unwrap();
        // ...but non-finite or non-positive link terms are not.
        let mut c = NodeCosts::homogeneous(base, 3);
        c.alpha[1] = 0.0;
        assert!(c.validate().is_err());
        let mut c = NodeCosts::homogeneous(base, 3);
        c.theta[2] = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = NodeCosts::homogeneous(base, 3);
        c.compute[0] = -1.0;
        assert!(c.validate().is_err());
        let mut c = NodeCosts::homogeneous(base, 3);
        c.theta.pop();
        assert!(c.validate().is_err(), "ragged table must be rejected");
    }

    #[test]
    fn virtual_clocks_match_scalar_clock_bitwise_when_homogeneous() {
        // The tentpole regression anchor: identical costs => every barrier
        // is a no-op and each node's accumulation is the scalar clock's.
        let base = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(8);
        let costs = NodeCosts::homogeneous(base, 8);
        let mut clocks = VirtualClocks::new(&topo);
        let mut scalar = SimClock::default();
        let d = 25_500_000;
        for step in 0..12 {
            let round = step % topo.rounds();
            let comm: Vec<f64> = (0..8)
                .map(|i| costs.gossip_node(i, topo.in_neighbors(i, round).len(), d))
                .collect();
            clocks.advance(&costs.compute, &comm, BarrierScope::Neighborhood { round });
            scalar.advance(base.compute + base.gossip(&topo, d));
        }
        let ar: Vec<f64> = (0..8).map(|i| costs.all_reduce_node(i, 8, d)).collect();
        clocks.advance(&costs.compute, &ar, BarrierScope::Global);
        scalar.advance(base.compute + base.all_reduce(8, d));
        for &s in clocks.seconds() {
            assert_eq!(s, scalar.seconds, "lockstep clock drifted from the scalar clock");
        }
        assert_eq!(clocks.max_seconds(), scalar.seconds);
        assert_eq!(clocks.slack(), 0.0);
        assert_eq!(clocks.total_wait(), 0.0);
    }

    #[test]
    fn straggler_slowness_propagates_one_hop_per_gossip_round() {
        // Ring of 6, node 0 computes 4x slower, free communication: after
        // ONE gossip round only 0's neighbors have waited; after a global
        // barrier everyone is at the straggler's clock.
        let base = CostModel { alpha: 1e-12, theta: 1e-18, compute: 1.0 };
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(base, 6).with_straggler(0, 4.0).unwrap();
        let mut clocks = VirtualClocks::new(&topo);
        let comm = vec![0.0; 6];
        clocks.advance(&costs.compute, &comm, BarrierScope::Neighborhood { round: 0 });
        // Step 1: no one has a lagging neighbor yet (all clocks were 0).
        assert!(clocks.waited().iter().all(|&w| w == 0.0));
        clocks.advance(&costs.compute, &comm, BarrierScope::Neighborhood { round: 0 });
        // Step 2: nodes 1 and 5 waited 3s for node 0; nodes 2..4 did not.
        assert_eq!(clocks.waited()[1], 3.0);
        assert_eq!(clocks.waited()[5], 3.0);
        assert_eq!(clocks.waited()[2], 0.0);
        assert_eq!(clocks.waited()[3], 0.0);
        assert!(clocks.slack() > 0.0);
        let before = clocks.max_seconds();
        clocks.advance(&costs.compute, &comm, BarrierScope::Global);
        assert_eq!(clocks.slack(), 3.0, "post-barrier spread is one step's compute gap");
        assert!(clocks.max_seconds() > before);
        assert!(clocks.total_wait() > 6.0);
    }

    #[test]
    fn latency_straggler_hurts_all_reduce_more_than_gossip() {
        // The §3.4 inequality under heterogeneity: All-Reduce pays the
        // straggler's alpha n times, one-peer gossip pays it once.
        let base = CostModel::calibrated_resnet50();
        let topo = Topology::one_peer_expo(16);
        let d = 25_500_000;
        let hom = NodeCosts::homogeneous(base, 16);
        let slow = hom.clone().with_straggler(3, 4.0).unwrap();
        let g_ratio = slow.gossip_critical(&topo, d) / hom.gossip_critical(&topo, d);
        let ar_ratio = slow.all_reduce_critical(&topo, d) / hom.all_reduce_critical(&topo, d);
        assert!(
            g_ratio < ar_ratio,
            "gossip degraded {g_ratio:.3}x, all-reduce {ar_ratio:.3}x"
        );
    }

    #[test]
    fn advance_one_and_stall_until_bill_single_nodes() {
        let topo = Topology::ring(3);
        let mut clocks = VirtualClocks::new(&topo);
        clocks.advance_one(1, 2.5);
        assert_eq!(clocks.seconds(), &[0.0, 2.5, 0.0][..]);
        assert_eq!(clocks.total_wait(), 0.0);
        // Stall forward: the gap is billed as wait.
        clocks.stall_until(0, 4.0);
        assert_eq!(clocks.seconds()[0], 4.0);
        assert_eq!(clocks.waited()[0], 4.0);
        // Stall to the past is a no-op.
        clocks.stall_until(1, 1.0);
        assert_eq!(clocks.seconds()[1], 2.5);
        assert_eq!(clocks.waited()[1], 0.0);
    }

    #[test]
    fn clocks_sync_and_restore_roundtrip() {
        let topo = Topology::ring(3);
        let mut clocks = VirtualClocks::new(&topo);
        clocks.advance(&[1.0, 2.0, 3.0], &[0.5, 0.5, 0.5], BarrierScope::None);
        assert_eq!(clocks.max_seconds(), 3.5);
        assert_eq!(clocks.min_seconds(), 1.5);
        clocks.sync();
        assert_eq!(clocks.slack(), 0.0);
        assert_eq!(clocks.total_wait(), (3.5 - 1.5) + (3.5 - 2.5));
        let secs: Vec<f64> = clocks.seconds().to_vec();
        let waits: Vec<f64> = clocks.waited().to_vec();
        let mut fresh = VirtualClocks::new(&topo);
        fresh.restore(&secs, &waits).unwrap();
        assert_eq!(fresh.seconds(), &secs[..]);
        assert_eq!(fresh.waited(), &waits[..]);
        assert!(fresh.restore(&secs[..2], &waits).is_err());
        fresh.restore_uniform(9.0);
        assert_eq!(fresh.seconds(), &[9.0, 9.0, 9.0][..]);
        assert_eq!(fresh.total_wait(), 0.0);
    }

    #[test]
    fn flat_clocks_bill_per_event_without_neighbor_tables() {
        let mut clocks = VirtualClocks::flat(4);
        assert_eq!(clocks.n(), 4);
        clocks.advance_one(2, 1.5);
        clocks.advance_one(2, 0.5);
        clocks.stall_until(0, 3.0);
        assert_eq!(clocks.seconds()[2], 2.0);
        assert_eq!(clocks.seconds()[0], 3.0);
        assert_eq!(clocks.waited()[0], 3.0);
        assert_eq!(clocks.total_wait(), 3.0);
        // Global barriers still work on a flat plane (no tables needed).
        clocks.advance(&[0.0; 4], &[1.0; 4], BarrierScope::Global);
        assert_eq!(clocks.slack(), 0.0);
        assert_eq!(clocks.max_seconds(), 4.0);
    }

    #[test]
    fn region_tiers_partition_nodes_and_scale_cross_links() {
        let map = RegionMap::tiers(10, 3, 1.0, 8.0).unwrap();
        assert_eq!(map.n(), 10);
        assert_eq!(map.regions(), 3);
        // ceil(10/3) = 4 nodes per block: [0..4), [4..8), [8..10).
        assert_eq!(map.region_of(0), 0);
        assert_eq!(map.region_of(3), 0);
        assert_eq!(map.region_of(4), 1);
        assert_eq!(map.region_of(9), 2);
        assert_eq!(map.factor(0, 3), 1.0, "intra-region");
        assert_eq!(map.factor(0, 4), 8.0, "cross-region");
        assert_eq!(map.factor(9, 1), 8.0);
        assert_eq!(map.factor(8, 9), 1.0);
    }

    #[test]
    fn region_map_validates_inputs() {
        assert!(RegionMap::tiers(4, 0, 1.0, 2.0).is_err(), "k = 0");
        assert!(RegionMap::tiers(4, 5, 1.0, 2.0).is_err(), "k > n");
        assert!(RegionMap::tiers(4, 2, 0.0, 2.0).is_err(), "zero factor");
        assert!(RegionMap::tiers(4, 2, 1.0, f64::NAN).is_err(), "NaN factor");
        assert!(RegionMap::from_parts(vec![0, 1], vec![1.0; 3], 2).is_err(), "table not k x k");
        assert!(RegionMap::from_parts(vec![0, 2], vec![1.0; 4], 2).is_err(), "region id >= k");
        assert!(
            RegionMap::from_parts(vec![0, 1], vec![1.0, -1.0, 1.0, 1.0], 2).is_err(),
            "negative multiplier"
        );
        let ok = RegionMap::from_parts(vec![1, 0], vec![1.0, 3.0, 5.0, 1.0], 2).unwrap();
        assert_eq!(ok.factor(0, 1), 5.0, "row-major [region(a)][region(b)]");
        assert_eq!(ok.factor(1, 0), 3.0);
    }
}
