//! Event-driven asynchronous gossip: AD-PSGD mixing on a per-link
//! discrete-event time plane.
//!
//! The barrier-billed time plane (PR 4) charges "a node starts iteration k
//! after its in-neighbors finish k-1" — a sound critical-path bound, but
//! one that exposes every transfer: a node can never overlap its compute
//! with a peer's in-flight message. This module is the finer regime the
//! ROADMAP names twice ("Fully asynchronous gossip (AD-PSGD)",
//! "Event-driven gossip billing"): a binary-heap event queue over typed
//! events — a node finishing its local update ([`Ev`] `READY`), a payload
//! completing its traversal of one directed link (`DELIVER`), a node
//! attempting its mix (`MIX`) — billed from [`NodeCosts`] per LINK, with an
//! [`AsyncGossip`] training regime on top (`train.regime async` /
//! `--regime async`) in which each node runs its own iteration counter,
//! pushes its post-update iterate to its out-neighbors as transfers
//! complete, and mixes whatever bounded-stale neighbor copies have arrived
//! (`--max-staleness`).
//!
//! §Semantics. Node j's *version-v payload* is its post-update, pre-mix
//! iterate of iteration v-1 (versions are 1-based so the broadcast initial
//! parameters are version 0). At iteration k node i mixes, for each
//! in-neighbor j of its current gossip round, the newest payload that has
//! *arrived* (delivery time <= i's clock), subject to the bound
//! `version >= (k+1) - max_staleness`; if the bound is violated the node
//! stalls until the enabling delivery (the stall accrues to its
//! barrier-wait account). The recorded staleness of a mix input is
//! `(k+1) - version` (0 = the BSP-fresh copy). Global averages (every k·H
//! for PGA/Local/SlowMo, every step for Parallel) remain full barriers:
//! every node halts at iteration k, one exact all-reduce runs, clocks
//! advance under [`BarrierScope::Global`] — the drain semantics the k·H
//! analysis needs. Eval, logging and checkpointing likewise drain: the
//! trainer's [`AsyncGossip::run_until`] leaves every node at the same
//! iteration count, so snapshots are always step boundaries (in-flight
//! payloads are snapshot/restored — checkpoint v5 — not dropped).
//!
//! §Billing, two modes.
//!
//! * **`max_staleness = 0` (strict).** Every mix must use the BSP-fresh
//!   copy, so every transfer is on the critical path and nothing can
//!   overlap — the regime degenerates to lockstep waves over the exact BSP
//!   schedule. The engine then bills each wave exactly the way the BSP
//!   trainer bills the same action — the backend's own per-node charge
//!   under the action's [`BarrierScope`], fused with the per-node compute
//!   — so the event-driven run reproduces the barrier-billed
//!   `sim_seconds` AND the BSP parameter trajectory **bit-exactly** on
//!   both CommPlane backends (the regression anchor; asserted by
//!   `rust/tests/eventsim.rs`). Every existing time table is therefore a
//!   regression gate for this subsystem.
//! * **`max_staleness >= 1` (event billing).** Transfers ride the links in
//!   the background: a push bills the sender `alpha_src` per message on
//!   its own clock (send initiation), then occupies the directed link for
//!   `theta_src * cost_dim` seconds — messages on one link serialize
//!   through its `busy_until` horizon, which is what the per-link
//!   utilization metric measures — and is delivered when the traversal
//!   completes. Compute is billed per node as it happens. Only a violated
//!   staleness bound puts a transfer back on a node's critical path, which
//!   is how async gossip hides stragglers and link latency that the
//!   neighborhood barrier must expose (GossipGraD, Daily et al. 2018;
//!   SGP, Assran et al. 2019) — `benches/tab17_comm_overhead.rs` gates
//!   async's critical path <= the neighborhood-barrier bill under seeded
//!   stragglers.
//!
//! §Determinism. Virtual times are exact f64 arithmetic on the cost
//! tables; the heap orders events by `(time, kind, src, dst, seq)` with
//! `f64::total_cmp`, so the event order is a pure function of the
//! configuration — identical at any worker-pool size (the pool only
//! shards the *real* gradient work, whose per-node arithmetic is
//! order-independent). `rust/tests/eventsim.rs` asserts trace equality
//! across pool sizes.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::algorithms::{AlgorithmKind, CommAction, FixedSchedule, Schedule};
use crate::comm::{CommBackend, CommStats};
use crate::coordinator::mixer::{mix_row_src, weight_rows_f32};
use crate::costmodel::{BarrierScope, NodeCosts, VirtualClocks};
use crate::exec::WorkerPool;
use crate::params::ParamMatrix;
use crate::topology::Topology;

/// Which execution regime drives the trainer's step loop
/// (`train.regime` / `--regime`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Regime {
    /// Bulk-synchronous: phases 1-2, then the communication action,
    /// synchronously (the default).
    #[default]
    Bsp,
    /// Double-buffered async gossip (PR 2): the round-t mix overlaps round
    /// t+1's sampling phase; bit-identical to BSP at every drained
    /// boundary.
    Overlap,
    /// Event-driven asynchronous gossip (this module): per-node iteration
    /// counters, bounded-stale mixing, per-link billing. Drops the BSP
    /// equivalence unless `max_staleness = 0`.
    Async,
}

impl Regime {
    pub fn from_name(name: &str) -> Result<Regime> {
        Ok(match name {
            "bsp" | "sync" => Regime::Bsp,
            "overlap" => Regime::Overlap,
            "async" | "adpsgd" => Regime::Async,
            other => bail!("unknown regime '{other}' (bsp | overlap | async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Regime::Bsp => "bsp",
            Regime::Overlap => "overlap",
            Regime::Async => "async",
        }
    }
}

/// Event kinds, in processing-priority order at equal times: a delivery at
/// time t is visible to a mix attempted at t.
const EV_DELIVER: u8 = 0;
const EV_MIX: u8 = 1;
const EV_READY: u8 = 2;

/// One queued event. Total order: `(time, kind, a, b, seq)` — `a`/`b` are
/// `(src, dst)` for deliveries and `(node, 0)` otherwise; `seq` is a
/// global monotone stamp that only breaks exact ties.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Ev {
    time: f64,
    kind: u8,
    a: u32,
    b: u32,
    seq: u64,
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.kind.cmp(&other.kind))
            .then(self.a.cmp(&other.a))
            .then(self.b.cmp(&other.b))
            .then(self.seq.cmp(&other.seq))
    }
}

/// One processed event, recorded when tracing is enabled (the
/// determinism-gate representation: time as raw bits so equality is
/// bitwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEv {
    pub kind: u8,
    pub a: u32,
    pub b: u32,
    pub iter: u32,
    pub time_bits: u64,
}

/// An in-flight message on one directed link.
#[derive(Clone, Debug, PartialEq)]
struct Msg {
    deliver_at: f64,
    version: u64,
    payload: Vec<f32>,
}

/// Per-directed-link state: the serialization horizon, the completed-
/// traversal occupancy the utilization column reads (accrued at delivery,
/// so in-flight time never counts), the newest *delivered* payload, and
/// the in-flight FIFO (delivery times are monotone per link).
#[derive(Clone, Debug)]
struct Link {
    src: usize,
    dst: usize,
    busy_until: f64,
    busy_seconds: f64,
    cache_version: u64,
    cache: Vec<f32>,
    inflight: VecDeque<Msg>,
}

/// Checkpointable snapshot of one link (v5 wire form).
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSnapshot {
    pub src: u32,
    pub dst: u32,
    pub busy_until: f64,
    pub busy_seconds: f64,
    pub cache_version: u64,
    pub cache: Vec<f32>,
    /// `(deliver_at, version, payload)` in FIFO order.
    pub inflight: Vec<(f64, u64, Vec<f32>)>,
}

/// Checkpointable engine state (the per-edge in-flight/stale block of
/// checkpoint v5). Exported at drained boundaries only, so no per-node
/// iteration counters are needed — every node sits at the trainer's step.
#[derive(Clone, Debug, PartialEq)]
pub struct EventSimState {
    pub max_staleness: u64,
    /// Staleness histogram: `hist[s]` mixes used a copy s versions old.
    pub hist: Vec<u64>,
    /// Links in ascending `(src, dst)` order — the engine's edge order.
    pub links: Vec<LinkSnapshot>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum NodeState {
    /// Waiting for the horizon to rise (between `run_until` calls).
    Parked,
    /// A READY or MIX event for this node is in the heap.
    Scheduled,
    /// Mix blocked on the staleness bound; resumed by a delivery.
    Waiting,
    /// Halted at a global-average barrier.
    Barrier,
}

/// The event-driven asynchronous gossip engine (see module docs). Owns
/// virtual-time state and the per-edge payload plane; real gradient work
/// and the global average are delegated to the caller through `step_fn` /
/// the [`CommBackend`].
pub struct AsyncGossip {
    n: usize,
    d: usize,
    max_staleness: usize,
    /// The fixed communication schedule (the async regime rejects
    /// adaptive schedules — Gossip-AGA consults the cluster-mean loss
    /// every step, which is undefined without a global step).
    sched: FixedSchedule,
    rounds: usize,
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    alpha: Vec<f64>,
    /// Per-sender link occupancy of one payload: `theta_src * cost_dim`.
    tx_seconds: Vec<f64>,
    /// Directed edges, ascending `(src, dst)`; `links` is index-aligned.
    edges: Vec<(usize, usize)>,
    /// Per-round transmit plan: `out_edges[r][src] = [(dst, link index)]`
    /// (precomputed so the hot push path does no search or allocation).
    out_edges: Vec<Vec<Vec<(usize, usize)>>>,
    /// Per-round receive plan: `in_links[r][i] = [(j, link index)]` over
    /// node i's round-r in-neighbors (self excluded) — the mix hot path's
    /// neighbor -> cache resolution, search-free.
    in_links: Vec<Vec<Vec<(usize, usize)>>>,
    links: Vec<Link>,
    done: Vec<usize>,
    round_ctr: Vec<usize>,
    state: Vec<NodeState>,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Nodes whose READY is scheduled but whose gradient has not run yet;
    /// flushed as one pool batch at the next READY pop.
    pending_exec: Vec<(usize, usize)>,
    barrier_waiting: usize,
    hist: Vec<u64>,
    zeros: Vec<f64>,
    scratch: Vec<f32>,
    trace: Option<Vec<TraceEv>>,
    strict: bool,
}

fn edge_index(edges: &[(usize, usize)], src: usize, dst: usize) -> usize {
    edges.binary_search(&(src, dst)).expect("gossip edge exists by construction")
}

fn max_of(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

impl AsyncGossip {
    /// Build the engine for `topo` under `costs`. `init` seeds every link
    /// cache with the broadcast initial parameters (version 0), exactly
    /// what a fresh BSP run would transmit first. `kind`/`h` select the
    /// fixed communication schedule.
    pub fn new(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        cost_dim: usize,
        max_staleness: usize,
        kind: AlgorithmKind,
        h: usize,
        init: &ParamMatrix,
    ) -> Result<AsyncGossip> {
        let n = topo.n;
        ensure!(costs.n() == n, "cost table covers {} nodes, topology has {n}", costs.n());
        ensure!(init.n() == n && init.d() == d, "init params must be {n} x {d}");
        if kind == AlgorithmKind::GossipAga {
            bail!(
                "the async regime supports fixed schedules only — Gossip-AGA adapts its \
                 period from the cluster-mean loss at every step, which is undefined \
                 without a global step (use --regime bsp or overlap)"
            );
        }
        let fs = FixedSchedule::for_kind(kind, h)?;
        let rounds = topo.rounds();
        let rows = weight_rows_f32(topo);
        let inn: Vec<Vec<Vec<usize>>> = (0..rounds)
            .map(|r| {
                (0..n)
                    .map(|i| {
                        topo.in_neighbors(i, r).into_iter().filter(|&j| j != i).collect()
                    })
                    .collect()
            })
            .collect();
        let outn: Vec<Vec<Vec<usize>>> =
            (0..rounds).map(|r| (0..n).map(|j| topo.out_neighbors(j, r)).collect()).collect();
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for per_round in &outn {
            for (src, dsts) in per_round.iter().enumerate() {
                for &dst in dsts {
                    edges.push((src, dst));
                }
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let out_edges: Vec<Vec<Vec<(usize, usize)>>> = outn
            .iter()
            .map(|per_node| {
                per_node
                    .iter()
                    .enumerate()
                    .map(|(src, dsts)| {
                        dsts.iter().map(|&dst| (dst, edge_index(&edges, src, dst))).collect()
                    })
                    .collect()
            })
            .collect();
        let in_links: Vec<Vec<Vec<(usize, usize)>>> = inn
            .iter()
            .map(|per_node| {
                per_node
                    .iter()
                    .enumerate()
                    .map(|(i, js)| {
                        js.iter().map(|&j| (j, edge_index(&edges, j, i))).collect()
                    })
                    .collect()
            })
            .collect();
        let links = edges
            .iter()
            .map(|&(src, dst)| Link {
                src,
                dst,
                busy_until: 0.0,
                busy_seconds: 0.0,
                cache_version: 0,
                cache: init.row(src).to_vec(),
                inflight: VecDeque::new(),
            })
            .collect();
        let tx_seconds = (0..n).map(|i| costs.theta[i] * cost_dim as f64).collect();
        Ok(AsyncGossip {
            n,
            d,
            max_staleness,
            sched: fs,
            rounds,
            rows,
            alpha: costs.alpha.clone(),
            tx_seconds,
            edges,
            out_edges,
            in_links,
            links,
            done: vec![0; n],
            round_ctr: vec![0; n],
            state: vec![NodeState::Parked; n],
            heap: BinaryHeap::new(),
            seq: 0,
            pending_exec: Vec::new(),
            barrier_waiting: 0,
            hist: Vec::new(),
            zeros: vec![0.0; n],
            scratch: vec![0.0; d],
            trace: None,
            strict: max_staleness == 0,
        })
    }

    /// The fixed schedule's action at iteration k — delegated to THE
    /// [`FixedSchedule::action`] implementation (stateless for fixed
    /// schedules; the clone sidesteps its `&mut` receiver), so the async
    /// regime's action sequence can never drift from the BSP trainer's.
    pub fn action_at(&self, k: usize) -> CommAction {
        self.sched.clone().action(k, 0.0)
    }

    /// Iterations every node has completed (equal across nodes at every
    /// drained boundary — i.e. whenever `run_until` has returned).
    pub fn iterations_done(&self) -> usize {
        self.done[0]
    }

    /// The staleness histogram: entry s counts mix inputs that were s
    /// versions behind BSP-fresh.
    pub fn histogram(&self) -> &[u64] {
        &self.hist
    }

    /// `(max, mean)` staleness over all mix inputs so far (0, 0.0 before
    /// any mix — and always, in strict mode).
    pub fn staleness(&self) -> (u64, f64) {
        let total: u64 = self.hist.iter().sum();
        if total == 0 {
            return (0, 0.0);
        }
        let max = self.hist.iter().rposition(|&c| c > 0).unwrap_or(0) as u64;
        let weighted: f64 = self.hist.iter().enumerate().map(|(s, &c)| s as f64 * c as f64).sum();
        (max, weighted / total as f64)
    }

    /// Mean per-link utilization at virtual time `now`: COMPLETED transfer
    /// occupancy divided by elapsed time, averaged over directed links
    /// (occupancy accrues when a traversal finishes, never while in
    /// flight, so each link's share stays within [0, 1]). 0 when no time
    /// has passed or the graph has no edges.
    pub fn link_utilization(&self, now: f64) -> f64 {
        if now <= 0.0 || self.links.is_empty() {
            return 0.0;
        }
        let total: f64 = self.links.iter().map(|l| l.busy_seconds / now).sum();
        total / self.links.len() as f64
    }

    /// Record every processed event (the determinism gate's probe).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    pub fn trace(&self) -> Option<&[TraceEv]> {
        self.trace.as_deref()
    }

    fn record(&mut self, kind: u8, a: usize, b: usize, iter: usize, time: f64) {
        if let Some(t) = self.trace.as_mut() {
            t.push(TraceEv {
                kind,
                a: a as u32,
                b: b as u32,
                iter: iter as u32,
                time_bits: time.to_bits(),
            });
        }
    }

    /// Snapshot the per-edge in-flight/stale state (checkpoint v5). Call
    /// only at drained boundaries (the trainer's checkpoint path).
    pub fn export_state(&self) -> EventSimState {
        EventSimState {
            max_staleness: self.max_staleness as u64,
            hist: self.hist.clone(),
            links: self
                .links
                .iter()
                .map(|l| LinkSnapshot {
                    src: l.src as u32,
                    dst: l.dst as u32,
                    busy_until: l.busy_until,
                    busy_seconds: l.busy_seconds,
                    cache_version: l.cache_version,
                    cache: l.cache.clone(),
                    inflight: l
                        .inflight
                        .iter()
                        .map(|m| (m.deliver_at, m.version, m.payload.clone()))
                        .collect(),
                })
                .collect(),
        }
    }

    /// Restore a [`EventSimState`] at step boundary `step` with
    /// `gossip_rounds` rounds already executed; rebuilds the delivery
    /// events for every in-flight payload in deterministic order.
    pub fn import_state(
        &mut self,
        state: &EventSimState,
        step: usize,
        gossip_rounds: usize,
    ) -> Result<()> {
        ensure!(
            state.max_staleness == self.max_staleness as u64,
            "checkpoint was written at max_staleness {}, this run uses {}",
            state.max_staleness,
            self.max_staleness
        );
        ensure!(
            state.links.len() == self.links.len(),
            "checkpoint carries {} links, engine has {}",
            state.links.len(),
            self.links.len()
        );
        self.reset_counters(step, gossip_rounds);
        self.hist = state.hist.clone();
        for (l, s) in self.links.iter_mut().zip(&state.links) {
            ensure!(
                (l.src, l.dst) == (s.src as usize, s.dst as usize),
                "checkpoint link ({}, {}) does not match engine edge ({}, {})",
                s.src,
                s.dst,
                l.src,
                l.dst
            );
            ensure!(
                s.cache.len() == self.d && s.inflight.iter().all(|(_, _, p)| p.len() == self.d),
                "checkpoint payloads on link ({}, {}) are not d = {}",
                s.src,
                s.dst,
                self.d
            );
            l.busy_until = s.busy_until;
            l.busy_seconds = s.busy_seconds;
            l.cache_version = s.cache_version;
            l.cache = s.cache.clone();
            l.inflight = s
                .inflight
                .iter()
                .map(|(t, v, p)| Msg { deliver_at: *t, version: *v, payload: p.clone() })
                .collect();
        }
        // Delivery events rebuild in ascending edge order; per-link FIFO
        // order is preserved by the seq stamps, and cross-link order at
        // equal times is decided by (src, dst) — exactly the original
        // run's total order.
        let evs: Vec<(f64, usize, usize)> = self
            .links
            .iter()
            .flat_map(|l| l.inflight.iter().map(|m| (m.deliver_at, l.src, l.dst)))
            .collect();
        for (t, src, dst) in evs {
            self.push_ev(t, EV_DELIVER, src, dst);
        }
        Ok(())
    }

    /// Re-seed from live parameters at step boundary `step` (resuming a
    /// pre-v5 / BSP-written checkpoint into the async regime): caches hold
    /// each node's current row at the boundary version, nothing in flight,
    /// link accounts zeroed.
    pub fn reset(&mut self, params: &ParamMatrix, step: usize, gossip_rounds: usize) {
        self.reset_counters(step, gossip_rounds);
        self.hist.clear();
        for l in self.links.iter_mut() {
            l.busy_until = 0.0;
            l.busy_seconds = 0.0;
            l.cache_version = step as u64;
            l.cache.copy_from_slice(params.row(l.src));
            l.inflight.clear();
        }
    }

    fn reset_counters(&mut self, step: usize, gossip_rounds: usize) {
        self.done.fill(step);
        self.round_ctr.fill(gossip_rounds);
        self.state.fill(NodeState::Parked);
        self.heap.clear();
        self.seq = 0;
        self.pending_exec.clear();
        self.barrier_waiting = 0;
    }

    fn push_ev(&mut self, time: f64, kind: u8, a: usize, b: usize) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { time, kind, a: a as u32, b: b as u32, seq }));
    }

    /// Advance the cluster until every node has completed `target`
    /// iterations; no node starts an iteration >= `target` (so the engine
    /// always returns at a drained step boundary). `step_fn` executes the
    /// local update (phases 1-2) for a batch of `(node, iteration)` pairs
    /// whose nodes are pairwise distinct; `sync_fn` runs after each global
    /// average (the SlowMo outer-update hook).
    #[allow(clippy::too_many_arguments)]
    pub fn run_until(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        debug_assert!(params.n() == self.n && params.d() == self.d);
        if self.strict {
            self.run_waves(target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
        } else {
            self.run_events(target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
        }
        // The backend's gossip-round clock is the checkpointed source of
        // truth; at a drained boundary every node agrees on it.
        backend.set_gossip_clock(self.round_ctr[0]);
        Ok(())
    }

    /// Strict mode (`max_staleness = 0`): lockstep waves that replicate
    /// the BSP trainer's operation and billing sequence exactly (see the
    /// module docs for why zero staleness degenerates to this).
    #[allow(clippy::too_many_arguments)]
    fn run_waves(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        while self.done[0] < target {
            let k = self.done[0];
            let batch: Vec<(usize, usize)> = (0..self.n).map(|i| (i, k)).collect();
            step_fn(params, &batch)?;
            let action = self.action_at(k);
            match action {
                CommAction::Gossip => {
                    let round = self.round_ctr[0] % self.rounds;
                    // Transmit: every payload actually traverses the
                    // backend (measured on the bus, predicted on shared);
                    // zero staleness means it is consumed this wave.
                    for src in 0..self.n {
                        let m = self.out_edges[round][src].len();
                        for t in 0..m {
                            let (dst, e) = self.out_edges[round][src][t];
                            let (payload, stats) = backend.push_row(params, src, dst)?;
                            backend.add_total(stats);
                            self.links[e].busy_seconds += self.tx_seconds[src];
                            self.links[e].inflight.push_back(Msg {
                                deliver_at: 0.0,
                                version: (k + 1) as u64,
                                payload,
                            });
                        }
                    }
                    // Deliver this wave's payloads (exactly version k+1
                    // per active in-edge), then run THE mix path — do_mix
                    // is the one copy of the kernel invocation, so the
                    // strict anchor and the relaxed regime cannot drift
                    // apart. Staleness is provably 0 here (fresh caches),
                    // and do_mix advances each node's round counter.
                    {
                        let Self { links, in_links, .. } = self;
                        for nbrs in &in_links[round] {
                            for &(_, e) in nbrs {
                                let l = &mut links[e];
                                let msg = l
                                    .inflight
                                    .pop_front()
                                    .expect("strict wave pushed this round's payload");
                                debug_assert_eq!(msg.version, (k + 1) as u64);
                                l.cache_version = msg.version;
                                l.cache = msg.payload;
                            }
                        }
                    }
                    for i in 0..self.n {
                        self.do_mix(i, k, round, params);
                    }
                    let node_seconds = backend.gossip_node_seconds(round);
                    backend.add_total(CommStats {
                        sim_seconds: max_of(&node_seconds),
                        ..Default::default()
                    });
                    clocks.advance(
                        &costs.compute,
                        &node_seconds,
                        BarrierScope::Neighborhood { round },
                    );
                }
                CommAction::GlobalAverage => {
                    let charge = backend.global_average(params, pool)?;
                    sync_fn(k, params)?;
                    clocks.advance(&costs.compute, &charge.node_seconds, charge.barrier);
                }
                CommAction::None => {
                    clocks.advance(&costs.compute, &self.zeros, BarrierScope::None);
                }
            }
            for dn in self.done.iter_mut() {
                *dn += 1;
            }
            self.record(EV_READY, 0, self.n, k, clocks.max_seconds());
        }
        Ok(())
    }

    /// Event billing (`max_staleness >= 1`): the discrete-event loop.
    #[allow(clippy::too_many_arguments)]
    fn run_events(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        // Raise the horizon: parked nodes resume at their own clocks (the
        // horizon is a simulation artifact, never billed).
        for i in 0..self.n {
            if self.state[i] == NodeState::Parked && self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            }
        }
        while !(0..self.n).all(|i| self.done[i] >= target) {
            let Some(Reverse(ev)) = self.heap.pop() else {
                bail!("event queue drained with nodes short of iteration {target}");
            };
            match ev.kind {
                EV_DELIVER => {
                    let (src, dst) = (ev.a as usize, ev.b as usize);
                    self.record(EV_DELIVER, src, dst, self.done[dst], ev.time);
                    self.on_deliver(src, dst, ev.time, target, params, clocks);
                }
                EV_MIX => {
                    let i = ev.a as usize;
                    self.record(EV_MIX, i, 0, self.done[i], ev.time);
                    self.on_mix(i, target, params, clocks);
                }
                EV_READY => {
                    let i = ev.a as usize;
                    self.record(EV_READY, i, 0, self.done[i], ev.time);
                    self.on_ready(i, target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
                }
                other => bail!("corrupt event kind {other}"),
            }
        }
        Ok(())
    }

    fn schedule_ready(&mut self, i: usize, t: f64) {
        self.state[i] = NodeState::Scheduled;
        self.pending_exec.push((i, self.done[i]));
        self.push_ev(t, EV_READY, i, 0);
    }

    /// Iteration k of node i is fully done at the node's current clock.
    fn complete(&mut self, i: usize, target: usize, clocks: &VirtualClocks) {
        self.done[i] += 1;
        if self.done[i] < target {
            self.schedule_ready(i, clocks.seconds()[i]);
        } else {
            self.state[i] = NodeState::Parked;
        }
    }

    /// Are node i's mix inputs for iteration k fresh enough? (Pure check —
    /// no mutation, usable from both the MIX and DELIVER handlers.)
    fn deps_met(&self, i: usize, k: usize, round: usize) -> bool {
        let need = ((k + 1) as u64).saturating_sub(self.max_staleness as u64);
        self.in_links[round][i].iter().all(|&(_, e)| self.links[e].cache_version >= need)
    }

    /// Execute node i's iteration-k mix from its caches; records the
    /// staleness of every input and advances the node's round counter.
    fn do_mix(&mut self, i: usize, k: usize, round: usize, params: &mut ParamMatrix) {
        let Self { links, rows, in_links, scratch, hist, .. } = self;
        let nbrs = &in_links[round][i];
        for &(_, e) in nbrs {
            let v = links[e].cache_version;
            let stale = ((k + 1) as u64).saturating_sub(v) as usize;
            if hist.len() <= stale {
                hist.resize(stale + 1, 0);
            }
            hist[stale] += 1;
        }
        mix_row_src(
            &rows[round][i],
            |j| {
                if j == i {
                    params.row(i)
                } else {
                    // Tiny linear scan over the precomputed (j, link)
                    // pairs — allocation- and search-free.
                    let &(_, e) = nbrs
                        .iter()
                        .find(|&&(jj, _)| jj == j)
                        .expect("weight row neighbors match the receive plan");
                    &links[e].cache
                }
            },
            scratch,
        );
        params.row_mut(i).copy_from_slice(scratch);
        self.round_ctr[i] += 1;
    }

    /// READY: flush pending gradients, bill compute, issue this
    /// iteration's pushes, then schedule the mix attempt (or park at the
    /// global-average barrier).
    #[allow(clippy::too_many_arguments)]
    fn on_ready(
        &mut self,
        i: usize,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        let k = self.done[i];
        if !self.pending_exec.is_empty() {
            // All scheduled-but-unexecuted gradients are independent (one
            // row, one RNG each — nodes pairwise distinct), so they run as
            // one pool batch regardless of their event times. Node i's own
            // entry is either in this batch or was flushed by an earlier
            // READY; either way its row is post-update by the time its
            // payloads ship below.
            let batch = std::mem::take(&mut self.pending_exec);
            step_fn(params, &batch)?;
        }
        clocks.advance_one(i, costs.compute[i]);
        match self.action_at(k) {
            CommAction::None => {
                self.complete(i, target, clocks);
            }
            CommAction::Gossip => {
                let round = self.round_ctr[i] % self.rounds;
                let m = self.out_edges[round][i].len();
                for t in 0..m {
                    let (dst, e) = self.out_edges[round][i][t];
                    // Send initiation on the node's clock, traversal on
                    // the link's serialization horizon.
                    clocks.advance_one(i, self.alpha[i]);
                    let issue = clocks.seconds()[i];
                    let (payload, mut stats) = backend.push_row(params, i, dst)?;
                    // sim_seconds keeps its "seconds of node time spent on
                    // communication" meaning: only the send initiation is
                    // on a node's clock; the payload traversal is link
                    // occupancy (the link-utilization column), not node
                    // time. Summed over messages this stays far BELOW the
                    // BSP bill of the same schedule — that gap is exactly
                    // the comm the async regime hides.
                    stats.sim_seconds = self.alpha[i];
                    backend.add_total(stats);
                    let l = &mut self.links[e];
                    let start = if l.busy_until > issue { l.busy_until } else { issue };
                    let deliver_at = start + self.tx_seconds[i];
                    l.busy_until = deliver_at;
                    l.inflight.push_back(Msg { deliver_at, version: (k + 1) as u64, payload });
                    self.push_ev(deliver_at, EV_DELIVER, i, dst);
                }
                self.push_ev(clocks.seconds()[i], EV_MIX, i, 0);
            }
            CommAction::GlobalAverage => {
                self.state[i] = NodeState::Barrier;
                self.barrier_waiting += 1;
                if self.barrier_waiting == self.n {
                    self.resolve_barrier(k, target, params, backend, pool, clocks, sync_fn)?;
                }
            }
        }
        Ok(())
    }

    /// MIX: attempt the bounded-stale mix at the node's own clock.
    fn on_mix(&mut self, i: usize, target: usize, params: &mut ParamMatrix, clocks: &mut VirtualClocks) {
        let k = self.done[i];
        let round = self.round_ctr[i] % self.rounds;
        if self.deps_met(i, k, round) {
            self.do_mix(i, k, round, params);
            self.complete(i, target, clocks);
        } else {
            self.state[i] = NodeState::Waiting;
        }
    }

    /// DELIVER: complete one link traversal; a node stalled on the
    /// staleness bound resumes at the enabling delivery time (the stall is
    /// billed to its barrier-wait account).
    fn on_deliver(
        &mut self,
        src: usize,
        dst: usize,
        t: f64,
        target: usize,
        params: &mut ParamMatrix,
        clocks: &mut VirtualClocks,
    ) {
        let e = edge_index(&self.edges, src, dst);
        let l = &mut self.links[e];
        let msg = l.inflight.pop_front().expect("a delivery event has a queued message");
        debug_assert_eq!(msg.deliver_at.to_bits(), t.to_bits());
        // Occupancy accrues at traversal COMPLETION: in-flight time never
        // counts toward utilization, so busy_seconds <= elapsed time and
        // the utilization column stays within [0, 1].
        l.busy_seconds += self.tx_seconds[src];
        if msg.version > l.cache_version {
            l.cache_version = msg.version;
            l.cache = msg.payload;
        }
        if self.state[dst] == NodeState::Waiting {
            let k = self.done[dst];
            let round = self.round_ctr[dst] % self.rounds;
            if self.deps_met(dst, k, round) {
                clocks.stall_until(dst, t);
                self.do_mix(dst, k, round, params);
                self.complete(dst, target, clocks);
            }
        }
    }

    /// All nodes halted at the iteration-k global average: run the exact
    /// all-reduce, fire the sync hook, advance the clocks under the full
    /// barrier, release everyone.
    #[allow(clippy::too_many_arguments)]
    fn resolve_barrier(
        &mut self,
        k: usize,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        debug_assert!(self.done.iter().all(|&dn| dn == k));
        let charge = backend.global_average(params, pool)?;
        sync_fn(k, params)?;
        clocks.advance(&self.zeros, &charge.node_seconds, charge.barrier);
        self.barrier_waiting = 0;
        for i in 0..self.n {
            self.done[i] += 1;
            if self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            } else {
                self.state[i] = NodeState::Parked;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommBackend, Compression, SharedBackend};
    use crate::costmodel::CostModel;
    use crate::rng::Rng;

    /// Deterministic synthetic local update: pure in (node, iter), so any
    /// execution order produces the same bits.
    fn fake_step(params: &mut ParamMatrix, batch: &[(usize, usize)]) -> Result<()> {
        for &(node, iter) in batch {
            let mut r = Rng::new(0xFEED ^ ((node as u64) << 32) ^ iter as u64);
            for x in params.row_mut(node) {
                *x = 0.9 * *x + 0.1 * r.normal() as f32;
            }
        }
        Ok(())
    }

    fn engine_run(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        s: usize,
        kind: AlgorithmKind,
        h: usize,
        steps: usize,
    ) -> (ParamMatrix, VirtualClocks, AsyncGossip) {
        let mut params = ParamMatrix::random(&mut Rng::new(5), topo.n, d, 1.0);
        let mut engine =
            AsyncGossip::new(topo, costs, d, 1000, s, kind, h, &params).unwrap();
        let mut backend = SharedBackend::new(topo, d, costs, 1000, Compression::None);
        let pool = WorkerPool::new(1);
        let mut clocks = VirtualClocks::new(topo);
        let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
        let mut sync = |_k: usize, _p: &mut ParamMatrix| -> Result<()> { Ok(()) };
        for t in 1..=steps {
            engine
                .run_until(t, &mut params, &mut backend, &pool, &mut clocks, costs, &mut step, &mut sync)
                .unwrap();
        }
        (params, clocks, engine)
    }

    #[test]
    fn strict_mode_matches_bsp_replay_bitwise() {
        let d = 17;
        for topo in [Topology::ring(6), Topology::one_peer_expo(8)] {
            let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
            let (ev_params, ev_clocks, _) =
                engine_run(&topo, &costs, d, 0, AlgorithmKind::GossipPga, 4, 11);
            // BSP reference: same updates, backend-level gossip, same billing.
            let mut params = ParamMatrix::random(&mut Rng::new(5), topo.n, d, 1.0);
            let mut backend = SharedBackend::new(&topo, d, &costs, 1000, Compression::None);
            let pool = WorkerPool::new(1);
            let mut clocks = VirtualClocks::new(&topo);
            for k in 0..11 {
                let batch: Vec<(usize, usize)> = (0..topo.n).map(|i| (i, k)).collect();
                fake_step(&mut params, &batch).unwrap();
                if (k + 1) % 4 == 0 {
                    let c = backend.global_average(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                } else {
                    let c = backend.gossip(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                }
            }
            assert_eq!(ev_params, params, "{:?}", topo.kind);
            assert_eq!(ev_clocks.seconds(), clocks.seconds(), "{:?}", topo.kind);
        }
    }

    #[test]
    fn relaxed_mode_respects_staleness_bound_and_runs_dry() {
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6)
            .with_straggler(0, 4.0)
            .unwrap();
        for s in [1usize, 3] {
            let (_, clocks, engine) =
                engine_run(&topo, &costs, 9, s, AlgorithmKind::Gossip, usize::MAX, 20);
            let (max, mean) = engine.staleness();
            assert!(max as usize <= s, "staleness {max} exceeded the bound {s}");
            assert!(mean >= 0.0);
            assert!(clocks.max_seconds() > 0.0);
            assert!(engine.link_utilization(clocks.max_seconds()) > 0.0);
        }
    }

    #[test]
    fn async_critical_path_beats_barrier_billing_under_straggler() {
        // The per-link overlap story at unit scale: with a 4x straggler on
        // a ring, the event plane's critical path undercuts the
        // neighborhood-barrier bill (which exposes every transfer).
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6)
            .with_straggler(0, 4.0)
            .unwrap();
        let steps = 16;
        let (_, ev_clocks, _) =
            engine_run(&topo, &costs, 9, 2, AlgorithmKind::Gossip, usize::MAX, steps);
        let mut clocks = VirtualClocks::new(&topo);
        let mut backend = SharedBackend::new(&topo, 9, &costs, 1000, Compression::None);
        let pool = WorkerPool::new(1);
        let mut params = ParamMatrix::random(&mut Rng::new(5), 6, 9, 1.0);
        for _ in 0..steps {
            let c = backend.gossip(&mut params, &pool).unwrap();
            clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
        }
        assert!(
            ev_clocks.max_seconds() < clocks.max_seconds(),
            "async {} !< barrier {}",
            ev_clocks.max_seconds(),
            clocks.max_seconds()
        );
    }

    #[test]
    fn export_import_roundtrips_and_validates() {
        let topo = Topology::ring(5);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 5)
            .with_straggler(1, 3.0)
            .unwrap();
        let (params, _, engine) =
            engine_run(&topo, &costs, 7, 2, AlgorithmKind::Gossip, usize::MAX, 9);
        let st = engine.export_state();
        let mut fresh =
            AsyncGossip::new(&topo, &costs, 7, 1000, 2, AlgorithmKind::Gossip, usize::MAX, &params)
                .unwrap();
        fresh.import_state(&st, 9, 9).unwrap();
        assert_eq!(fresh.export_state(), st);
        // Mismatched staleness bound is rejected.
        let mut wrong =
            AsyncGossip::new(&topo, &costs, 7, 1000, 1, AlgorithmKind::Gossip, usize::MAX, &params)
                .unwrap();
        assert!(wrong.import_state(&st, 9, 9).is_err());
    }

    #[test]
    fn regime_names_roundtrip() {
        for r in [Regime::Bsp, Regime::Overlap, Regime::Async] {
            assert_eq!(Regime::from_name(r.name()).unwrap(), r);
        }
        assert!(Regime::from_name("warp").is_err());
        assert_eq!(Regime::default(), Regime::Bsp);
    }

    #[test]
    fn aga_is_rejected() {
        let topo = Topology::ring(4);
        let costs = NodeCosts::homogeneous(CostModel::generic(), 4);
        let init = ParamMatrix::zeros(4, 3);
        assert!(
            AsyncGossip::new(&topo, &costs, 3, 100, 1, AlgorithmKind::GossipAga, 8, &init).is_err()
        );
    }
}
