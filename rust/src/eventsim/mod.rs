//! Event-driven asynchronous gossip: AD-PSGD mixing on a per-link
//! discrete-event time plane.
//!
//! The barrier-billed time plane (PR 4) charges "a node starts iteration k
//! after its in-neighbors finish k-1" — a sound critical-path bound, but
//! one that exposes every transfer: a node can never overlap its compute
//! with a peer's in-flight message. This module is the finer regime the
//! ROADMAP names twice ("Fully asynchronous gossip (AD-PSGD)",
//! "Event-driven gossip billing"): a binary-heap event queue over typed
//! events — a node finishing its local update ([`Ev`] `READY`), a payload
//! completing its traversal of one directed link (`DELIVER`), a node
//! attempting its mix (`MIX`), a scripted population change (`CHURN`) —
//! billed from [`NodeCosts`] per LINK, with an [`AsyncGossip`] training
//! regime on top (`train.regime async` / `--regime async`) in which each
//! node runs its own iteration counter, pushes its post-update iterate to
//! its out-neighbors as transfers complete, and mixes whatever
//! bounded-stale neighbor copies have arrived (`--max-staleness`).
//!
//! §Semantics. Node j's *version-v payload* is its post-update, pre-mix
//! iterate of iteration v-1 (versions are 1-based so the broadcast initial
//! parameters are version 0). At iteration k node i mixes, for each
//! in-neighbor j of its current gossip round, the newest payload that has
//! *arrived* (delivery time <= i's clock), subject to the bound
//! `version >= (k+1) - max_staleness`; if the bound is violated the node
//! stalls until the enabling delivery (the stall accrues to its
//! barrier-wait account). The recorded staleness of a mix input is
//! `(k+1) - version` (0 = the BSP-fresh copy). Global averages (every k·H
//! for PGA/Local/SlowMo, every step for Parallel) remain full barriers:
//! every node halts at iteration k, one exact all-reduce runs, clocks
//! advance under [`BarrierScope::Global`] — the drain semantics the k·H
//! analysis needs. Eval, logging and checkpointing likewise drain: the
//! trainer's [`AsyncGossip::run_until`] leaves every node at the same
//! iteration count, so snapshots are always step boundaries (in-flight
//! payloads are snapshot/restored — checkpoint v5/v6 — not dropped).
//!
//! §Population plane (PR 6). Node identity is split from payload storage:
//!
//! * **Materialized workers** (today's behavior, [`AsyncGossip::new`]) own
//!   a [`ParamMatrix`] row and run real gradient steps through the
//!   [`CommBackend`]. Their link caches and in-flight messages now hold
//!   [`PayloadHandle`]s into a ref-counted [`PayloadPool`] interned by
//!   `(src, version)` — one payload per pushed iterate instead of one copy
//!   per directed edge — without changing a single parameter, clock, or
//!   traffic bit (interned payloads are byte-identical by construction;
//!   the async regime rejects compression, so one version of one node is
//!   one byte pattern).
//! * **Virtual nodes** ([`AsyncGossip::new_virtual`]) carry the full
//!   event-plane state — clocks, staleness, link occupancy, traffic
//!   accounting — but no model: their "training" is a deterministic AR(1)
//!   drift (dense at a small `--dim`, or the `(mean, var)` statistical
//!   surrogate when `--surrogate` / `dim = 0` is set), so the engine
//!   reaches n = 10^5 in O(n + edges) memory with **zero** dense scalars
//!   allocated in surrogate mode (asserted via the pool's audit
//!   counters). Virtual runs support scripted churn ([`ChurnEvent`]:
//!   crash, rejoin, flaky-link, restore — the SGP/GossipGraD scenarios)
//!   and per-region latency tiers ([`RegionMap`]); traffic is
//!   self-accounted into a [`CommStats`] total since no backend exists at
//!   that scale.
//!
//! Churn semantics: a crashed node freezes (its iteration counter stops;
//! a crash mid-iteration loses the in-progress work, which is redone on
//! rejoin — earlier in-flight payloads still deliver and are deduped by
//! version). Crashed senders stop gating their receivers' staleness bound,
//! and global-average barriers synchronize the *alive* population only; a
//! node that rejoins behind an already-resolved barrier skips it
//! (`missed_barriers` counts these). A rejoining node's offline span lands
//! in its wait column (`stall_until`), so slack accounting still closes.
//!
//! §Billing, two modes.
//!
//! * **`max_staleness = 0` (strict).** Every mix must use the BSP-fresh
//!   copy, so every transfer is on the critical path and nothing can
//!   overlap — the regime degenerates to lockstep waves over the exact BSP
//!   schedule. The engine then bills each wave exactly the way the BSP
//!   trainer bills the same action — the backend's own per-node charge
//!   under the action's [`BarrierScope`], fused with the per-node compute
//!   — so the event-driven run reproduces the barrier-billed
//!   `sim_seconds` AND the BSP parameter trajectory **bit-exactly** on
//!   both CommPlane backends (the regression anchor; asserted by
//!   `rust/tests/eventsim.rs`). Every existing time table is therefore a
//!   regression gate for this subsystem.
//! * **`max_staleness >= 1` (event billing).** Transfers ride the links in
//!   the background: a push bills the sender `alpha_src` per message on
//!   its own clock (send initiation), then occupies the directed link for
//!   `theta_src * cost_dim` seconds — scaled by the link's flaky
//!   multiplier and the sender→receiver region factor on the virtual
//!   plane — messages on one link serialize through its `busy_until`
//!   horizon, which is what the per-link utilization metric measures —
//!   and is delivered when the traversal completes. Compute is billed per
//!   node as it happens. Only a violated staleness bound puts a transfer
//!   back on a node's critical path, which is how async gossip hides
//!   stragglers and link latency that the neighborhood barrier must
//!   expose (GossipGraD, Daily et al. 2018; SGP, Assran et al. 2019) —
//!   `benches/tab17_comm_overhead.rs` gates async's critical path <= the
//!   neighborhood-barrier bill under seeded stragglers.
//!
//! §Determinism. Virtual times are exact f64 arithmetic on the cost
//! tables; the heap orders events by `(time, kind, src, dst, seq)` with
//! `f64::total_cmp`, so the event order is a pure function of the
//! configuration — identical at any worker-pool size (the pool only
//! shards the *real* gradient work, whose per-node arithmetic is
//! order-independent), and identical across replays of the same churn
//! script (the churn gate in `rust/tests/population.rs`). Churn events at
//! a node-event's exact instant process after it (CHURN is the
//! highest-numbered kind).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::algorithms::{AlgorithmKind, CommAction, FixedSchedule, Schedule};
use crate::comm::{CommBackend, CommStats};
use crate::coordinator::mixer::{mix_row_src, weight_rows_f32};
use crate::costmodel::{BarrierScope, NodeCosts, RegionMap, VirtualClocks};
use crate::exec::WorkerPool;
use crate::params::pool::{Payload, PayloadHandle, PayloadPool};
use crate::params::ParamMatrix;
use crate::rng::Rng;
use crate::topology::Topology;

/// Which execution regime drives the trainer's step loop
/// (`train.regime` / `--regime`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Regime {
    /// Bulk-synchronous: phases 1-2, then the communication action,
    /// synchronously (the default).
    #[default]
    Bsp,
    /// Double-buffered async gossip (PR 2): the round-t mix overlaps round
    /// t+1's sampling phase; bit-identical to BSP at every drained
    /// boundary.
    Overlap,
    /// Event-driven asynchronous gossip (this module): per-node iteration
    /// counters, bounded-stale mixing, per-link billing. Drops the BSP
    /// equivalence unless `max_staleness = 0`.
    Async,
}

impl Regime {
    pub fn from_name(name: &str) -> Result<Regime> {
        Ok(match name {
            "bsp" | "sync" => Regime::Bsp,
            "overlap" => Regime::Overlap,
            "async" | "adpsgd" => Regime::Async,
            other => bail!("unknown regime '{other}' (bsp | overlap | async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Regime::Bsp => "bsp",
            Regime::Overlap => "overlap",
            Regime::Async => "async",
        }
    }
}

/// Event kinds, in processing-priority order at equal times: a delivery at
/// time t is visible to a mix attempted at t; churn at t applies after the
/// node events of that instant.
const EV_DELIVER: u8 = 0;
const EV_MIX: u8 = 1;
const EV_READY: u8 = 2;
const EV_CHURN: u8 = 3;

/// One queued event. Total order: `(time, kind, a, b, seq)` — `a`/`b` are
/// `(src, dst)` for deliveries, `(node, generation)` for virtual-plane
/// READY/MIX, `(script index, 0)` for churn, and `(node, 0)` otherwise;
/// `seq` is a global monotone stamp that only breaks exact ties.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Ev {
    time: f64,
    kind: u8,
    a: u32,
    b: u32,
    seq: u64,
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.kind.cmp(&other.kind))
            .then(self.a.cmp(&other.a))
            .then(self.b.cmp(&other.b))
            .then(self.seq.cmp(&other.seq))
    }
}

/// One processed event, recorded when tracing is enabled (the
/// determinism-gate representation: time as raw bits so equality is
/// bitwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEv {
    pub kind: u8,
    pub a: u32,
    pub b: u32,
    pub iter: u32,
    pub time_bits: u64,
}

/// One scripted population change on the virtual plane. Times are virtual
/// seconds; node/link identities are validated at construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnEvent {
    /// Node leaves the population: its clock freezes, its in-progress
    /// iteration is lost (redone on rejoin), its receivers stop waiting
    /// for it.
    Crash { at: f64, node: usize },
    /// Node returns: its offline span accrues to its wait account, and it
    /// resumes at its frozen iteration counter (skipping any barrier the
    /// live population resolved while it was away).
    Rejoin { at: f64, node: usize },
    /// The directed link slows by `factor` (> 1) or speeds up (< 1): every
    /// subsequent traversal takes `factor * theta_src * cost_dim` seconds.
    FlakyLink { at: f64, src: usize, dst: usize, factor: f64 },
    /// The directed link returns to its nominal speed.
    LinkRestore { at: f64, src: usize, dst: usize },
}

impl ChurnEvent {
    pub fn at(&self) -> f64 {
        match *self {
            ChurnEvent::Crash { at, .. }
            | ChurnEvent::Rejoin { at, .. }
            | ChurnEvent::FlakyLink { at, .. }
            | ChurnEvent::LinkRestore { at, .. } => at,
        }
    }
}

/// Configuration of a virtual population (see
/// [`AsyncGossip::new_virtual`]).
#[derive(Clone, Debug, Default)]
pub struct VirtualConfig {
    /// Dense drift dimension; 0 selects the `(mean, var)` statistical
    /// surrogate (no dense scalar is ever allocated).
    pub dim: usize,
    /// Seeds the initial population state and the per-(node, iteration)
    /// drift — the whole sweep is a pure function of (config, seed).
    pub seed: u64,
    /// Scripted churn; validated (and rejected with a clear message)
    /// before any event runs.
    pub churn: Vec<ChurnEvent>,
    /// Optional per-region latency tiers multiplying link traversal times.
    pub regions: Option<RegionMap>,
}

/// An in-flight message on one directed link. `tx` is the traversal time
/// billed to the link's occupancy at delivery (already scaled by the
/// flaky/region multipliers in force when the push was issued).
#[derive(Debug)]
struct Msg {
    deliver_at: f64,
    version: u64,
    payload: PayloadHandle,
    tx: f64,
}

/// Per-directed-link state: the serialization horizon, the completed-
/// traversal occupancy the utilization column reads (accrued at delivery,
/// so in-flight time never counts), the newest *delivered* payload (a pool
/// handle, not a copy), and the in-flight FIFO (delivery times are
/// monotone per link).
#[derive(Debug)]
struct Link {
    src: usize,
    dst: usize,
    busy_until: f64,
    busy_seconds: f64,
    cache_version: u64,
    cache: PayloadHandle,
    /// Flaky-link traversal multiplier (1.0 nominal), set by churn.
    tx_mult: f64,
    inflight: VecDeque<Msg>,
}

/// One checkpointed payload slot (v6 wire form): the slot table is the
/// deduplicated storage plane, referenced by index from every link.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotSnapshot {
    pub version: u64,
    pub payload: Payload,
}

/// Checkpointable snapshot of one link (v6 wire form): payloads are slot
/// indices into [`EventSimState::slots`].
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSnapshot {
    pub src: u32,
    pub dst: u32,
    pub busy_until: f64,
    pub busy_seconds: f64,
    pub cache_version: u64,
    pub cache_slot: u32,
    /// `(deliver_at, version, slot)` in FIFO order.
    pub inflight: Vec<(f64, u64, u32)>,
}

/// Checkpointable engine state (the per-edge in-flight/stale block of
/// checkpoint v6; v5 files are converted on load). Exported at drained
/// boundaries only, so no per-node iteration counters are needed — every
/// node sits at the trainer's step. Slot order is canonical first-seen
/// (links ascending, cache then inflight FIFO), so export is a pure
/// function of engine state.
#[derive(Clone, Debug, PartialEq)]
pub struct EventSimState {
    pub max_staleness: u64,
    /// Staleness histogram: `hist[s]` mixes used a copy s versions old.
    pub hist: Vec<u64>,
    /// Deduplicated payload storage referenced by the links.
    pub slots: Vec<SlotSnapshot>,
    /// Links in ascending `(src, dst)` order — the engine's edge order.
    pub links: Vec<LinkSnapshot>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum NodeState {
    /// Waiting for the horizon to rise (between `run_until` calls), or
    /// crashed.
    Parked,
    /// A READY or MIX event for this node is in the heap.
    Scheduled,
    /// Mix blocked on the staleness bound; resumed by a delivery (or by
    /// the blocking sender crashing).
    Waiting,
    /// Halted at a global-average barrier.
    Barrier,
}

/// The virtual population's drift/accounting state (absent on the
/// materialized plane).
struct VirtPlane {
    surrogate: bool,
    /// Dense drift state (n x dim) when `!surrogate`; 0 x 0 otherwise.
    state: ParamMatrix,
    /// Surrogate per-node mean/variance when `surrogate`.
    smean: Vec<f64>,
    svar: Vec<f64>,
    seed: u64,
    /// Self-accounted traffic (no backend exists at population scale).
    stats: CommStats,
    crashes: u64,
    rejoins: u64,
    link_events: u64,
    missed_barriers: u64,
}

/// Per-round static graph plan shared by both constructors.
struct GraphPlan {
    rounds: usize,
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    edges: Vec<(usize, usize)>,
    out_edges: Vec<Vec<Vec<(usize, usize)>>>,
    in_links: Vec<Vec<Vec<(usize, usize)>>>,
}

fn plan_graph(topo: &Topology) -> GraphPlan {
    let n = topo.n;
    let rounds = topo.rounds();
    let rows = weight_rows_f32(topo);
    let inn: Vec<Vec<Vec<usize>>> = (0..rounds)
        .map(|r| {
            (0..n)
                .map(|i| topo.in_neighbors(i, r).into_iter().filter(|&j| j != i).collect())
                .collect()
        })
        .collect();
    let outn: Vec<Vec<Vec<usize>>> =
        (0..rounds).map(|r| (0..n).map(|j| topo.out_neighbors(j, r)).collect()).collect();
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for per_round in &outn {
        for (src, dsts) in per_round.iter().enumerate() {
            for &dst in dsts {
                edges.push((src, dst));
            }
        }
    }
    edges.sort_unstable();
    edges.dedup();
    let out_edges: Vec<Vec<Vec<(usize, usize)>>> = outn
        .iter()
        .map(|per_node| {
            per_node
                .iter()
                .enumerate()
                .map(|(src, dsts)| {
                    dsts.iter().map(|&dst| (dst, edge_index(&edges, src, dst))).collect()
                })
                .collect()
        })
        .collect();
    let in_links: Vec<Vec<Vec<(usize, usize)>>> = inn
        .iter()
        .map(|per_node| {
            per_node
                .iter()
                .enumerate()
                .map(|(i, js)| js.iter().map(|&j| (j, edge_index(&edges, j, i))).collect())
                .collect()
        })
        .collect();
    GraphPlan { rounds, rows, edges, out_edges, in_links }
}

/// The event-driven asynchronous gossip engine (see module docs). Owns
/// virtual-time state and the pooled payload plane; real gradient work
/// and the global average are delegated to the caller through `step_fn` /
/// the [`CommBackend`] (materialized plane), or replaced by the drift
/// model (virtual plane).
pub struct AsyncGossip {
    n: usize,
    d: usize,
    max_staleness: usize,
    /// The fixed communication schedule (the async regime rejects
    /// adaptive schedules — Gossip-AGA consults the cluster-mean loss
    /// every step, which is undefined without a global step).
    sched: FixedSchedule,
    rounds: usize,
    rows: Vec<Vec<Vec<(usize, f32)>>>,
    alpha: Vec<f64>,
    theta: Vec<f64>,
    compute: Vec<f64>,
    cost_dim: usize,
    /// Per-sender nominal link occupancy of one payload:
    /// `theta_src * cost_dim` (before flaky/region multipliers).
    tx_seconds: Vec<f64>,
    /// Directed edges, ascending `(src, dst)`; `links` is index-aligned.
    edges: Vec<(usize, usize)>,
    /// Per-round transmit plan: `out_edges[r][src] = [(dst, link index)]`
    /// (precomputed so the hot push path does no search or allocation).
    out_edges: Vec<Vec<Vec<(usize, usize)>>>,
    /// Per-round receive plan: `in_links[r][i] = [(j, link index)]` over
    /// node i's round-r in-neighbors (self excluded) — the mix hot path's
    /// neighbor -> cache resolution, search-free.
    in_links: Vec<Vec<Vec<(usize, usize)>>>,
    links: Vec<Link>,
    /// Ref-counted payload storage behind every link cache and message.
    store: PayloadPool,
    /// Intern payloads by `(src, version)` (one slot per pushed iterate).
    /// Always on in production; the off switch exists so tests can prove
    /// pool shape never changes a bit.
    intern: bool,
    done: Vec<usize>,
    round_ctr: Vec<usize>,
    state: Vec<NodeState>,
    /// Population membership; all-true (and constant) on the materialized
    /// plane.
    alive: Vec<bool>,
    alive_count: usize,
    /// Per-node event generation: bumped on every crash/rejoin so stale
    /// READY/MIX events left in the heap by a churned node are skipped.
    gen: Vec<u32>,
    virt: Option<VirtPlane>,
    regions: Option<RegionMap>,
    churn: Vec<ChurnEvent>,
    /// Iterations whose global-average barrier has resolved (rejoiners
    /// behind this skip the barrier and count a miss).
    barrier_epoch: u64,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Nodes whose READY is scheduled but whose gradient has not run yet;
    /// flushed as one pool batch at the next READY pop (materialized
    /// plane only).
    pending_exec: Vec<(usize, usize)>,
    barrier_waiting: usize,
    hist: Vec<u64>,
    zeros: Vec<f64>,
    scratch: Vec<f32>,
    trace: Option<Vec<TraceEv>>,
    strict: bool,
}

fn edge_index(edges: &[(usize, usize)], src: usize, dst: usize) -> usize {
    edges.binary_search(&(src, dst)).expect("gossip edge exists by construction")
}

fn max_of(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

impl AsyncGossip {
    /// Build the materialized engine for `topo` under `costs`. `init`
    /// seeds every link cache with the broadcast initial parameters
    /// (version 0), exactly what a fresh BSP run would transmit first.
    /// `kind`/`h` select the fixed communication schedule.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        cost_dim: usize,
        max_staleness: usize,
        kind: AlgorithmKind,
        h: usize,
        init: &ParamMatrix,
    ) -> Result<AsyncGossip> {
        Self::new_with_storage(topo, costs, d, cost_dim, max_staleness, kind, h, init, true)
    }

    /// [`AsyncGossip::new`] with the payload-intern switch exposed
    /// (`intern = false` gives every link its own slot — the PR 5 storage
    /// shape — so tests can assert pooling changes no bit).
    #[allow(clippy::too_many_arguments)]
    pub fn new_with_storage(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        cost_dim: usize,
        max_staleness: usize,
        kind: AlgorithmKind,
        h: usize,
        init: &ParamMatrix,
        intern: bool,
    ) -> Result<AsyncGossip> {
        let n = topo.n;
        ensure!(init.n() == n && init.d() == d, "init params must be {n} x {d}");
        let mut seed_cache = |store: &mut PayloadPool, src: usize| {
            if intern {
                store.intern_dense(src as u32, 0, || init.row(src).to_vec())
            } else {
                store.insert_dense(0, init.row(src).to_vec())
            }
        };
        Self::assemble(
            topo,
            costs,
            d,
            cost_dim,
            max_staleness,
            kind,
            h,
            intern,
            None,
            None,
            Vec::new(),
            &mut seed_cache,
        )
    }

    /// Build a virtual population: n nodes with full event/clock/traffic
    /// state but pooled drift payloads instead of model rows — the
    /// configuration that reaches n = 10^5 (see module docs §Population
    /// plane). Drive it with [`AsyncGossip::run_virtual_until`].
    pub fn new_virtual(
        topo: &Topology,
        costs: &NodeCosts,
        cost_dim: usize,
        max_staleness: usize,
        kind: AlgorithmKind,
        h: usize,
        cfg: VirtualConfig,
    ) -> Result<AsyncGossip> {
        let n = topo.n;
        let surrogate = cfg.dim == 0;
        let (smean, svar, state) = if surrogate {
            let mut r = Rng::new(cfg.seed);
            let smean: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            (smean, vec![0.0; n], ParamMatrix::zeros(0, 0))
        } else {
            let state = ParamMatrix::random(&mut Rng::new(cfg.seed), n, cfg.dim, 1.0);
            (Vec::new(), Vec::new(), state)
        };
        let virt = VirtPlane {
            surrogate,
            state: state.clone(),
            smean: smean.clone(),
            svar,
            seed: cfg.seed,
            stats: CommStats::default(),
            crashes: 0,
            rejoins: 0,
            link_events: 0,
            missed_barriers: 0,
        };
        let mut seed_cache = |store: &mut PayloadPool, src: usize| {
            if surrogate {
                store.intern_stat(src as u32, 0, smean[src], 0.0)
            } else {
                store.intern_dense(src as u32, 0, || state.row(src).to_vec())
            }
        };
        Self::assemble(
            topo,
            costs,
            cfg.dim,
            cost_dim,
            max_staleness,
            kind,
            h,
            true,
            Some(virt),
            cfg.regions,
            cfg.churn,
            &mut seed_cache,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        cost_dim: usize,
        max_staleness: usize,
        kind: AlgorithmKind,
        h: usize,
        intern: bool,
        virt: Option<VirtPlane>,
        regions: Option<RegionMap>,
        churn: Vec<ChurnEvent>,
        seed_cache: &mut dyn FnMut(&mut PayloadPool, usize) -> PayloadHandle,
    ) -> Result<AsyncGossip> {
        let n = topo.n;
        ensure!(costs.n() == n, "cost table covers {} nodes, topology has {n}", costs.n());
        if kind == AlgorithmKind::GossipAga {
            bail!(
                "the async regime supports fixed schedules only — Gossip-AGA adapts its \
                 period from the cluster-mean loss at every step, which is undefined \
                 without a global step (use --regime bsp or overlap)"
            );
        }
        if let Some(r) = &regions {
            ensure!(r.n() == n, "region map covers {} nodes, topology has {n}", r.n());
        }
        let fs = FixedSchedule::for_kind(kind, h)?;
        let plan = plan_graph(topo);
        for (idx, ev) in churn.iter().enumerate() {
            let at = ev.at();
            ensure!(at.is_finite() && at >= 0.0, "churn event {idx}: time {at} must be >= 0");
            match *ev {
                ChurnEvent::Crash { node, .. } | ChurnEvent::Rejoin { node, .. } => {
                    ensure!(node < n, "churn event {idx}: node {node} out of range for {n} nodes");
                }
                ChurnEvent::FlakyLink { src, dst, factor, .. } => {
                    ensure!(
                        src < n && dst < n,
                        "churn event {idx}: link ({src}, {dst}) out of range for {n} nodes"
                    );
                    ensure!(
                        plan.edges.binary_search(&(src, dst)).is_ok(),
                        "churn event {idx}: ({src}, {dst}) is not a gossip edge of this topology"
                    );
                    ensure!(
                        factor.is_finite() && factor > 0.0,
                        "churn event {idx}: flaky factor {factor} must be finite and positive"
                    );
                }
                ChurnEvent::LinkRestore { src, dst, .. } => {
                    ensure!(
                        src < n && dst < n,
                        "churn event {idx}: link ({src}, {dst}) out of range for {n} nodes"
                    );
                    ensure!(
                        plan.edges.binary_search(&(src, dst)).is_ok(),
                        "churn event {idx}: ({src}, {dst}) is not a gossip edge of this topology"
                    );
                }
            }
        }
        let mut store = PayloadPool::new(d);
        let links: Vec<Link> = plan
            .edges
            .iter()
            .map(|&(src, dst)| Link {
                src,
                dst,
                busy_until: 0.0,
                busy_seconds: 0.0,
                cache_version: 0,
                cache: seed_cache(&mut store, src),
                tx_mult: 1.0,
                inflight: VecDeque::new(),
            })
            .collect();
        let tx_seconds = (0..n).map(|i| costs.theta[i] * cost_dim as f64).collect();
        let mut eng = AsyncGossip {
            n,
            d,
            max_staleness,
            sched: fs,
            rounds: plan.rounds,
            rows: plan.rows,
            alpha: costs.alpha.clone(),
            theta: costs.theta.clone(),
            compute: costs.compute.clone(),
            cost_dim,
            tx_seconds,
            edges: plan.edges,
            out_edges: plan.out_edges,
            in_links: plan.in_links,
            links,
            store,
            intern,
            done: vec![0; n],
            round_ctr: vec![0; n],
            state: vec![NodeState::Parked; n],
            alive: vec![true; n],
            alive_count: n,
            gen: vec![0; n],
            virt,
            regions,
            churn,
            barrier_epoch: 0,
            heap: BinaryHeap::new(),
            seq: 0,
            pending_exec: Vec::new(),
            barrier_waiting: 0,
            hist: Vec::new(),
            zeros: vec![0.0; n],
            scratch: vec![0.0; d],
            trace: None,
            strict: max_staleness == 0,
        };
        for idx in 0..eng.churn.len() {
            let t = eng.churn[idx].at();
            eng.push_ev(t, EV_CHURN, idx, 0);
        }
        Ok(eng)
    }

    /// The fixed schedule's action at iteration k — delegated to THE
    /// [`FixedSchedule::action`] implementation (stateless for fixed
    /// schedules; the clone sidesteps its `&mut` receiver), so the async
    /// regime's action sequence can never drift from the BSP trainer's.
    pub fn action_at(&self, k: usize) -> CommAction {
        self.sched.clone().action(k, 0.0)
    }

    /// Iterations every node has completed (equal across nodes at every
    /// drained boundary — i.e. whenever `run_until` has returned).
    pub fn iterations_done(&self) -> usize {
        self.done[0]
    }

    /// Iterations completed by the slowest *live* node (the virtual
    /// plane's progress measure under churn).
    pub fn min_alive_done(&self) -> usize {
        (0..self.n).filter(|&i| self.alive[i]).map(|i| self.done[i]).min().unwrap_or(0)
    }

    /// The staleness histogram: entry s counts mix inputs that were s
    /// versions behind BSP-fresh.
    pub fn histogram(&self) -> &[u64] {
        &self.hist
    }

    /// `(max, mean)` staleness over all mix inputs so far (0, 0.0 before
    /// any mix — and always, in strict mode).
    pub fn staleness(&self) -> (u64, f64) {
        let total: u64 = self.hist.iter().sum();
        if total == 0 {
            return (0, 0.0);
        }
        let max = self.hist.iter().rposition(|&c| c > 0).unwrap_or(0) as u64;
        let weighted: f64 = self.hist.iter().enumerate().map(|(s, &c)| s as f64 * c as f64).sum();
        (max, weighted / total as f64)
    }

    /// Mean per-link utilization at virtual time `now`: COMPLETED transfer
    /// occupancy divided by elapsed time, averaged over directed links
    /// (occupancy accrues when a traversal finishes, never while in
    /// flight, so each link's share stays within [0, 1]). 0 when no time
    /// has passed or the graph has no edges.
    pub fn link_utilization(&self, now: f64) -> f64 {
        if now <= 0.0 || self.links.is_empty() {
            return 0.0;
        }
        let total: f64 = self.links.iter().map(|l| l.busy_seconds / now).sum();
        total / self.links.len() as f64
    }

    /// Directed links in the engine (the denominator of the pool-size
    /// audit: live slots must stay far below this at scale).
    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    /// The payload pool (audit counters: peak live slots / dense scalars).
    pub fn store(&self) -> &PayloadPool {
        &self.store
    }

    /// Per-node liveness (all-true on the materialized plane).
    pub fn alive(&self) -> &[bool] {
        &self.alive
    }

    pub fn alive_count(&self) -> usize {
        self.alive_count
    }

    /// True when this engine was built by [`AsyncGossip::new_virtual`].
    pub fn is_virtual(&self) -> bool {
        self.virt.is_some()
    }

    /// Self-accounted traffic totals of a virtual run (zero for
    /// materialized engines — those bill through the [`CommBackend`]).
    pub fn virt_stats(&self) -> CommStats {
        self.virt.as_ref().map_or_else(CommStats::default, |v| v.stats)
    }

    /// `(crashes, rejoins, link events, missed barriers)` applied so far.
    pub fn churn_counts(&self) -> (u64, u64, u64, u64) {
        self.virt
            .as_ref()
            .map_or((0, 0, 0, 0), |v| (v.crashes, v.rejoins, v.link_events, v.missed_barriers))
    }

    /// Surrogate per-node means (None unless a surrogate virtual run).
    pub fn virt_means(&self) -> Option<&[f64]> {
        self.virt.as_ref().filter(|v| v.surrogate).map(|v| v.smean.as_slice())
    }

    /// Surrogate per-node variances (None unless a surrogate virtual run).
    pub fn virt_vars(&self) -> Option<&[f64]> {
        self.virt.as_ref().filter(|v| v.surrogate).map(|v| v.svar.as_slice())
    }

    /// Dense drift state (None unless a dense virtual run).
    pub fn virt_dense(&self) -> Option<&ParamMatrix> {
        self.virt.as_ref().filter(|v| !v.surrogate).map(|v| &v.state)
    }

    /// Record every processed event (the determinism gate's probe).
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    pub fn trace(&self) -> Option<&[TraceEv]> {
        self.trace.as_deref()
    }

    fn record(&mut self, kind: u8, a: usize, b: usize, iter: usize, time: f64) {
        if crate::obs::enabled() {
            // Event-plane probes: zero-width instants stamped with the
            // event's virtual time; deliveries attribute to the receiver,
            // everything else to the acting node.
            let (phase, node) = match kind {
                EV_DELIVER => (crate::obs::Phase::EvDeliver, b),
                EV_MIX => (crate::obs::Phase::EvMix, a),
                EV_READY => (crate::obs::Phase::EvReady, a),
                _ => (crate::obs::Phase::EvChurn, a),
            };
            crate::obs::instant(phase, node as u32, time);
        }
        if let Some(t) = self.trace.as_mut() {
            t.push(TraceEv {
                kind,
                a: a as u32,
                b: b as u32,
                iter: iter as u32,
                time_bits: time.to_bits(),
            });
        }
    }

    /// Snapshot the per-edge in-flight/stale state (checkpoint v6). Call
    /// only at drained boundaries (the trainer's checkpoint path;
    /// materialized engines only).
    pub fn export_state(&self) -> EventSimState {
        assert!(self.virt.is_none(), "virtual populations do not checkpoint");
        let mut slot_of: HashMap<u32, u32> = HashMap::new();
        let mut slots: Vec<SlotSnapshot> = Vec::new();
        let mut links_out = Vec::with_capacity(self.links.len());
        for l in &self.links {
            let mut map = |h: PayloadHandle| -> u32 {
                *slot_of.entry(h.index()).or_insert_with(|| {
                    let idx = slots.len() as u32;
                    slots.push(SlotSnapshot {
                        version: self.store.version(h),
                        payload: self.store.payload(h).clone(),
                    });
                    idx
                })
            };
            let cache_slot = map(l.cache);
            let inflight =
                l.inflight.iter().map(|m| (m.deliver_at, m.version, map(m.payload))).collect();
            links_out.push(LinkSnapshot {
                src: l.src as u32,
                dst: l.dst as u32,
                busy_until: l.busy_until,
                busy_seconds: l.busy_seconds,
                cache_version: l.cache_version,
                cache_slot,
                inflight,
            });
        }
        EventSimState {
            max_staleness: self.max_staleness as u64,
            hist: self.hist.clone(),
            slots,
            links: links_out,
        }
    }

    /// Restore a [`EventSimState`] at step boundary `step` with
    /// `gossip_rounds` rounds already executed; rebuilds the delivery
    /// events for every in-flight payload in deterministic order. All
    /// validation happens before any engine state is touched.
    pub fn import_state(
        &mut self,
        state: &EventSimState,
        step: usize,
        gossip_rounds: usize,
    ) -> Result<()> {
        ensure!(self.virt.is_none(), "virtual populations do not restore checkpoints");
        ensure!(
            state.max_staleness == self.max_staleness as u64,
            "checkpoint was written at max_staleness {}, this run uses {}",
            state.max_staleness,
            self.max_staleness
        );
        ensure!(
            state.links.len() == self.links.len(),
            "checkpoint carries {} links, engine has {}",
            state.links.len(),
            self.links.len()
        );
        let n_slots = state.slots.len() as u32;
        for (idx, s) in state.slots.iter().enumerate() {
            if let Payload::Dense(v) = &s.payload {
                ensure!(
                    v.len() == self.d,
                    "checkpoint slot {idx} payload is {} scalars, engine d = {}",
                    v.len(),
                    self.d
                );
            }
        }
        for (l, s) in self.links.iter().zip(&state.links) {
            ensure!(
                (l.src, l.dst) == (s.src as usize, s.dst as usize),
                "checkpoint link ({}, {}) does not match engine edge ({}, {})",
                s.src,
                s.dst,
                l.src,
                l.dst
            );
            ensure!(
                s.cache_slot < n_slots && s.inflight.iter().all(|&(_, _, sl)| sl < n_slots),
                "checkpoint link ({}, {}) references a slot outside the {} slot table",
                s.src,
                s.dst,
                n_slots
            );
        }
        self.reset_counters(step, gossip_rounds);
        self.hist = state.hist.clone();
        for e in 0..self.links.len() {
            while let Some(m) = self.links[e].inflight.pop_front() {
                self.store.release(m.payload);
            }
            let old = self.links[e].cache;
            // The link keeps the stale handle until it is rewired below;
            // nothing reads caches between here and the rewiring loop.
            self.store.release(old);
        }
        let handles: Vec<PayloadHandle> = state
            .slots
            .iter()
            .map(|s| match &s.payload {
                Payload::Dense(v) => self.store.insert_dense(s.version, v.clone()),
                Payload::Stat { mean, var } => self.store.insert_stat(s.version, *mean, *var),
            })
            .collect();
        for (e, s) in state.links.iter().enumerate() {
            let ch = handles[s.cache_slot as usize];
            self.store.retain(ch);
            let src = s.src as usize;
            {
                let l = &mut self.links[e];
                l.busy_until = s.busy_until;
                l.busy_seconds = s.busy_seconds;
                l.cache_version = s.cache_version;
                l.cache = ch;
            }
            for &(t, v, slot) in &s.inflight {
                let h = handles[slot as usize];
                self.store.retain(h);
                let tx = self.tx_seconds[src];
                self.links[e].inflight.push_back(Msg { deliver_at: t, version: v, payload: h, tx });
            }
        }
        for h in handles {
            self.store.release(h);
        }
        // Delivery events rebuild in ascending edge order; per-link FIFO
        // order is preserved by the seq stamps, and cross-link order at
        // equal times is decided by (src, dst) — exactly the original
        // run's total order.
        let evs: Vec<(f64, usize, usize)> = self
            .links
            .iter()
            .flat_map(|l| l.inflight.iter().map(|m| (m.deliver_at, l.src, l.dst)))
            .collect();
        for (t, src, dst) in evs {
            self.push_ev(t, EV_DELIVER, src, dst);
        }
        Ok(())
    }

    /// Re-seed from live parameters at step boundary `step` (resuming a
    /// pre-v5 / BSP-written checkpoint into the async regime): caches hold
    /// each node's current row at the boundary version, nothing in flight,
    /// link accounts zeroed.
    pub fn reset(&mut self, params: &ParamMatrix, step: usize, gossip_rounds: usize) {
        assert!(self.virt.is_none(), "reset is a materialized-plane operation");
        self.reset_counters(step, gossip_rounds);
        self.hist.clear();
        for e in 0..self.links.len() {
            while let Some(m) = self.links[e].inflight.pop_front() {
                self.store.release(m.payload);
            }
            let src = self.links[e].src;
            let h = if self.intern {
                self.store.intern_dense(src as u32, step as u64, || params.row(src).to_vec())
            } else {
                self.store.insert_dense(step as u64, params.row(src).to_vec())
            };
            let old = std::mem::replace(&mut self.links[e].cache, h);
            self.store.release(old);
            let l = &mut self.links[e];
            l.busy_until = 0.0;
            l.busy_seconds = 0.0;
            l.cache_version = step as u64;
        }
    }

    fn reset_counters(&mut self, step: usize, gossip_rounds: usize) {
        self.done.fill(step);
        self.round_ctr.fill(gossip_rounds);
        self.state.fill(NodeState::Parked);
        self.heap.clear();
        self.seq = 0;
        self.pending_exec.clear();
        self.barrier_waiting = 0;
    }

    fn push_ev(&mut self, time: f64, kind: u8, a: usize, b: usize) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { time, kind, a: a as u32, b: b as u32, seq }));
    }

    /// Advance the cluster until every node has completed `target`
    /// iterations; no node starts an iteration >= `target` (so the engine
    /// always returns at a drained step boundary). `step_fn` executes the
    /// local update (phases 1-2) for a batch of `(node, iteration)` pairs
    /// whose nodes are pairwise distinct; `sync_fn` runs after each global
    /// average (the SlowMo outer-update hook).
    #[allow(clippy::too_many_arguments)]
    pub fn run_until(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        ensure!(self.virt.is_none(), "virtual populations run through run_virtual_until");
        debug_assert!(params.n() == self.n && params.d() == self.d);
        if self.strict {
            self.run_waves(target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
        } else {
            self.run_events(target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
        }
        // The backend's gossip-round clock is the checkpointed source of
        // truth; at a drained boundary every node agrees on it.
        backend.set_gossip_clock(self.round_ctr[0]);
        Ok(())
    }

    /// Strict mode (`max_staleness = 0`): lockstep waves that replicate
    /// the BSP trainer's operation and billing sequence exactly (see the
    /// module docs for why zero staleness degenerates to this).
    #[allow(clippy::too_many_arguments)]
    fn run_waves(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        while self.done[0] < target {
            let k = self.done[0];
            let batch: Vec<(usize, usize)> = (0..self.n).map(|i| (i, k)).collect();
            step_fn(params, &batch)?;
            let action = self.action_at(k);
            match action {
                CommAction::Gossip => {
                    let round = self.round_ctr[0] % self.rounds;
                    // Transmit: every payload actually traverses the
                    // backend (measured on the bus, predicted on shared);
                    // zero staleness means it is consumed this wave.
                    for src in 0..self.n {
                        let m = self.out_edges[round][src].len();
                        for t in 0..m {
                            let (dst, e) = self.out_edges[round][src][t];
                            let (payload, stats) = backend.push_row(params, src, dst)?;
                            backend.add_total(stats);
                            let h = if self.intern {
                                self.store.intern_dense(src as u32, (k + 1) as u64, move || payload)
                            } else {
                                self.store.insert_dense((k + 1) as u64, payload)
                            };
                            self.links[e].busy_seconds += self.tx_seconds[src];
                            self.links[e].inflight.push_back(Msg {
                                deliver_at: 0.0,
                                version: (k + 1) as u64,
                                payload: h,
                                tx: 0.0,
                            });
                        }
                    }
                    // Deliver this wave's payloads (exactly version k+1
                    // per active in-edge), then run THE mix path — do_mix
                    // is the one copy of the kernel invocation, so the
                    // strict anchor and the relaxed regime cannot drift
                    // apart. Staleness is provably 0 here (fresh caches),
                    // and do_mix advances each node's round counter.
                    {
                        let Self { links, in_links, store, .. } = self;
                        for nbrs in &in_links[round] {
                            for &(_, e) in nbrs {
                                let l = &mut links[e];
                                let msg = l
                                    .inflight
                                    .pop_front()
                                    .expect("strict wave pushed this round's payload");
                                debug_assert_eq!(msg.version, (k + 1) as u64);
                                l.cache_version = msg.version;
                                let old = std::mem::replace(&mut l.cache, msg.payload);
                                store.release(old);
                            }
                        }
                    }
                    for i in 0..self.n {
                        self.do_mix(i, k, round, params);
                    }
                    let node_seconds = backend.gossip_node_seconds(round);
                    backend.add_total(CommStats {
                        sim_seconds: max_of(&node_seconds),
                        ..Default::default()
                    });
                    clocks.advance(
                        &costs.compute,
                        &node_seconds,
                        BarrierScope::Neighborhood { round },
                    );
                }
                CommAction::GlobalAverage => {
                    let charge = backend.global_average(params, pool)?;
                    sync_fn(k, params)?;
                    clocks.advance(&costs.compute, &charge.node_seconds, charge.barrier);
                }
                CommAction::None => {
                    clocks.advance(&costs.compute, &self.zeros, BarrierScope::None);
                }
            }
            for dn in self.done.iter_mut() {
                *dn += 1;
            }
            self.record(EV_READY, 0, self.n, k, clocks.max_seconds());
        }
        Ok(())
    }

    /// Event billing (`max_staleness >= 1`): the discrete-event loop.
    #[allow(clippy::too_many_arguments)]
    fn run_events(
        &mut self,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        // Raise the horizon: parked nodes resume at their own clocks (the
        // horizon is a simulation artifact, never billed).
        for i in 0..self.n {
            if self.state[i] == NodeState::Parked && self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            }
        }
        while !(0..self.n).all(|i| self.done[i] >= target) {
            let Some(Reverse(ev)) = self.heap.pop() else {
                bail!("event queue drained with nodes short of iteration {target}");
            };
            match ev.kind {
                EV_DELIVER => {
                    let (src, dst) = (ev.a as usize, ev.b as usize);
                    self.record(EV_DELIVER, src, dst, self.done[dst], ev.time);
                    self.on_deliver(src, dst, ev.time, target, params, clocks);
                }
                EV_MIX => {
                    let i = ev.a as usize;
                    self.record(EV_MIX, i, 0, self.done[i], ev.time);
                    self.on_mix(i, target, params, clocks);
                }
                EV_READY => {
                    let i = ev.a as usize;
                    self.record(EV_READY, i, 0, self.done[i], ev.time);
                    self.on_ready(i, target, params, backend, pool, clocks, costs, step_fn, sync_fn)?;
                }
                other => bail!("corrupt event kind {other}"),
            }
        }
        Ok(())
    }

    /// Advance a virtual population until every LIVE node has completed
    /// `target` iterations (crashed nodes are exempt; they resume their
    /// frozen counters on rejoin). Pair with a clock plane made by
    /// [`VirtualClocks::flat`] — the virtual plane bills through
    /// `advance_one`/`stall_until` only, so the per-round neighbor tables
    /// are never needed.
    pub fn run_virtual_until(&mut self, target: usize, clocks: &mut VirtualClocks) -> Result<()> {
        ensure!(self.virt.is_some(), "run_virtual_until requires an engine built by new_virtual");
        ensure!(clocks.n() == self.n, "clock plane covers {} nodes, engine has {}", clocks.n(), self.n);
        for i in 0..self.n {
            if self.alive[i] && self.state[i] == NodeState::Parked && self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            }
        }
        while !(0..self.n).all(|i| !self.alive[i] || self.done[i] >= target) {
            let Some(Reverse(ev)) = self.heap.pop() else {
                bail!("event queue drained with live nodes short of iteration {target}");
            };
            match ev.kind {
                EV_DELIVER => {
                    let (src, dst) = (ev.a as usize, ev.b as usize);
                    self.record(EV_DELIVER, src, dst, self.done[dst], ev.time);
                    self.on_deliver_virtual(src, dst, ev.time, target, clocks);
                }
                EV_MIX => {
                    let i = ev.a as usize;
                    if ev.b != self.gen[i] {
                        continue; // stale event from before a crash/rejoin
                    }
                    self.record(EV_MIX, i, 0, self.done[i], ev.time);
                    self.on_mix_virtual(i, target, clocks);
                }
                EV_READY => {
                    let i = ev.a as usize;
                    if ev.b != self.gen[i] {
                        continue; // stale event from before a crash/rejoin
                    }
                    self.record(EV_READY, i, 0, self.done[i], ev.time);
                    self.on_ready_virtual(i, target, clocks);
                }
                EV_CHURN => {
                    let idx = ev.a as usize;
                    self.record(EV_CHURN, idx, 0, 0, ev.time);
                    self.on_churn(idx, ev.time, target, clocks)?;
                }
                other => bail!("corrupt event kind {other}"),
            }
        }
        Ok(())
    }

    fn schedule_ready(&mut self, i: usize, t: f64) {
        self.state[i] = NodeState::Scheduled;
        if self.virt.is_none() {
            self.pending_exec.push((i, self.done[i]));
            self.push_ev(t, EV_READY, i, 0);
        } else {
            let g = self.gen[i] as usize;
            self.push_ev(t, EV_READY, i, g);
        }
    }

    /// Iteration k of node i is fully done at the node's current clock.
    fn complete(&mut self, i: usize, target: usize, clocks: &VirtualClocks) {
        self.done[i] += 1;
        if self.done[i] < target {
            self.schedule_ready(i, clocks.seconds()[i]);
        } else {
            self.state[i] = NodeState::Parked;
        }
    }

    /// Are node i's mix inputs for iteration k fresh enough? (Pure check —
    /// no mutation, usable from both the MIX and DELIVER handlers.) A
    /// crashed sender never gates its receivers: it cannot produce a
    /// fresher version, so waiting on it would deadlock the population.
    fn deps_met(&self, i: usize, k: usize, round: usize) -> bool {
        let need = ((k + 1) as u64).saturating_sub(self.max_staleness as u64);
        self.in_links[round][i]
            .iter()
            .all(|&(j, e)| !self.alive[j] || self.links[e].cache_version >= need)
    }

    /// Execute node i's iteration-k mix from its caches; records the
    /// staleness of every input and advances the node's round counter.
    fn do_mix(&mut self, i: usize, k: usize, round: usize, params: &mut ParamMatrix) {
        let Self { links, rows, in_links, scratch, hist, store, .. } = self;
        let nbrs = &in_links[round][i];
        for &(_, e) in nbrs {
            let v = links[e].cache_version;
            let stale = ((k + 1) as u64).saturating_sub(v) as usize;
            if hist.len() <= stale {
                hist.resize(stale + 1, 0);
            }
            hist[stale] += 1;
        }
        mix_row_src(
            &rows[round][i],
            |j| {
                if j == i {
                    params.row(i)
                } else {
                    // Tiny linear scan over the precomputed (j, link)
                    // pairs — allocation- and search-free.
                    let &(_, e) = nbrs
                        .iter()
                        .find(|&&(jj, _)| jj == j)
                        .expect("weight row neighbors match the receive plan");
                    store.dense(links[e].cache)
                }
            },
            scratch,
        );
        params.row_mut(i).copy_from_slice(scratch);
        self.round_ctr[i] += 1;
    }

    /// The virtual-plane mix: same weight rows and staleness accounting,
    /// applied to the drift state. A dead in-neighbor's weight folds into
    /// the self weight (its cache is its last word — mixing a corpse's
    /// stale iterate forever would bias the consensus curve).
    fn do_mix_virtual(&mut self, i: usize, k: usize, round: usize) {
        let Self { links, rows, in_links, scratch, hist, store, alive, virt, round_ctr, .. } = self;
        let virt = virt.as_mut().expect("virtual plane");
        let nbrs = &in_links[round][i];
        for &(j, e) in nbrs {
            if !alive[j] {
                continue;
            }
            let v = links[e].cache_version;
            let stale = ((k + 1) as u64).saturating_sub(v) as usize;
            if hist.len() <= stale {
                hist.resize(stale + 1, 0);
            }
            hist[stale] += 1;
        }
        if virt.surrogate {
            let mut mean = 0.0f64;
            let mut var = 0.0f64;
            let mut wself = 0.0f64;
            for &(j, w) in &rows[round][i] {
                if j == i || !alive[j] {
                    wself += w as f64;
                    continue;
                }
                let &(_, e) = nbrs
                    .iter()
                    .find(|&&(jj, _)| jj == j)
                    .expect("weight row neighbors match the receive plan");
                let (mj, vj) = store.stat(links[e].cache);
                mean += w as f64 * mj;
                var += (w as f64) * (w as f64) * vj;
            }
            mean += wself * virt.smean[i];
            var += wself * wself * virt.svar[i];
            virt.smean[i] = mean;
            virt.svar[i] = var;
        } else {
            scratch.fill(0.0);
            let mut wself = 0.0f32;
            for &(j, w) in &rows[round][i] {
                if j == i || !alive[j] {
                    wself += w;
                    continue;
                }
                let &(_, e) = nbrs
                    .iter()
                    .find(|&&(jj, _)| jj == j)
                    .expect("weight row neighbors match the receive plan");
                for (o, v) in scratch.iter_mut().zip(store.dense(links[e].cache)) {
                    *o += w * *v;
                }
            }
            for (o, v) in scratch.iter_mut().zip(virt.state.row(i)) {
                *o += wself * *v;
            }
            virt.state.copy_row_from(i, scratch);
        }
        round_ctr[i] += 1;
    }

    /// READY: flush pending gradients, bill compute, issue this
    /// iteration's pushes, then schedule the mix attempt (or park at the
    /// global-average barrier).
    #[allow(clippy::too_many_arguments)]
    fn on_ready(
        &mut self,
        i: usize,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        costs: &NodeCosts,
        step_fn: &mut dyn FnMut(&mut ParamMatrix, &[(usize, usize)]) -> Result<()>,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        let k = self.done[i];
        if !self.pending_exec.is_empty() {
            // All scheduled-but-unexecuted gradients are independent (one
            // row, one RNG each — nodes pairwise distinct), so they run as
            // one pool batch regardless of their event times. Node i's own
            // entry is either in this batch or was flushed by an earlier
            // READY; either way its row is post-update by the time its
            // payloads ship below.
            let batch = std::mem::take(&mut self.pending_exec);
            step_fn(params, &batch)?;
        }
        clocks.advance_one(i, costs.compute[i]);
        match self.action_at(k) {
            CommAction::None => {
                self.complete(i, target, clocks);
            }
            CommAction::Gossip => {
                let round = self.round_ctr[i] % self.rounds;
                let m = self.out_edges[round][i].len();
                for t in 0..m {
                    let (dst, e) = self.out_edges[round][i][t];
                    // Send initiation on the node's clock, traversal on
                    // the link's serialization horizon.
                    clocks.advance_one(i, self.alpha[i]);
                    let issue = clocks.seconds()[i];
                    let (payload, mut stats) = backend.push_row(params, i, dst)?;
                    // sim_seconds keeps its "seconds of node time spent on
                    // communication" meaning: only the send initiation is
                    // on a node's clock; the payload traversal is link
                    // occupancy (the link-utilization column), not node
                    // time. Summed over messages this stays far BELOW the
                    // BSP bill of the same schedule — that gap is exactly
                    // the comm the async regime hides.
                    stats.sim_seconds = self.alpha[i];
                    backend.add_total(stats);
                    let h = if self.intern {
                        self.store.intern_dense(i as u32, (k + 1) as u64, move || payload)
                    } else {
                        self.store.insert_dense((k + 1) as u64, payload)
                    };
                    let tx = self.tx_seconds[i];
                    let l = &mut self.links[e];
                    let start = if l.busy_until > issue { l.busy_until } else { issue };
                    let deliver_at = start + tx;
                    l.busy_until = deliver_at;
                    l.inflight.push_back(Msg { deliver_at, version: (k + 1) as u64, payload: h, tx });
                    self.push_ev(deliver_at, EV_DELIVER, i, dst);
                }
                self.push_ev(clocks.seconds()[i], EV_MIX, i, 0);
            }
            CommAction::GlobalAverage => {
                self.state[i] = NodeState::Barrier;
                self.barrier_waiting += 1;
                if self.barrier_waiting == self.n {
                    self.resolve_barrier(k, target, params, backend, pool, clocks, sync_fn)?;
                }
            }
        }
        Ok(())
    }

    /// Virtual READY: run the drift update in place of the gradient, bill
    /// compute, push pooled payloads (self-accounted traffic), schedule
    /// the mix — or park at the live-population barrier.
    fn on_ready_virtual(&mut self, i: usize, target: usize, clocks: &mut VirtualClocks) {
        let k = self.done[i];
        // Drift is a pure function of (seed, node, iteration) — a crashed
        // node that redoes iteration k on rejoin recomputes the same
        // state, keeping replays bit-exact.
        {
            let virt = self.virt.as_mut().expect("virtual plane");
            let mut r = Rng::new(virt.seed ^ ((i as u64) << 32) ^ k as u64);
            if virt.surrogate {
                virt.smean[i] = 0.9 * virt.smean[i] + 0.1 * r.normal();
                virt.svar[i] = 0.81 * virt.svar[i] + 0.01;
            } else {
                for x in virt.state.row_mut(i) {
                    *x = 0.9 * *x + 0.1 * r.normal() as f32;
                }
            }
        }
        clocks.advance_one(i, self.compute[i]);
        match self.action_at(k) {
            CommAction::None => {
                self.complete(i, target, clocks);
            }
            CommAction::Gossip => {
                let round = self.round_ctr[i] % self.rounds;
                let v = (k + 1) as u64;
                let alpha = self.alpha[i];
                let cost_dim = self.cost_dim as u64;
                let m = self.out_edges[round][i].len();
                for t in 0..m {
                    let (dst, e) = self.out_edges[round][i][t];
                    clocks.advance_one(i, alpha);
                    let issue = clocks.seconds()[i];
                    let h = {
                        let Self { store, virt, .. } = self;
                        let virt = virt.as_ref().expect("virtual plane");
                        if virt.surrogate {
                            store.intern_stat(i as u32, v, virt.smean[i], virt.svar[i])
                        } else {
                            store.intern_dense(i as u32, v, || virt.state.row(i).to_vec())
                        }
                    };
                    {
                        let virt = self.virt.as_mut().expect("virtual plane");
                        virt.stats.scalars_sent += cost_dim;
                        virt.stats.msgs += 1;
                        virt.stats.sim_seconds += alpha;
                    }
                    let region = self.regions.as_ref().map_or(1.0, |r| r.factor(i, dst));
                    let tx = self.tx_seconds[i] * self.links[e].tx_mult * region;
                    let l = &mut self.links[e];
                    let start = if l.busy_until > issue { l.busy_until } else { issue };
                    let deliver_at = start + tx;
                    l.busy_until = deliver_at;
                    l.inflight.push_back(Msg { deliver_at, version: v, payload: h, tx });
                    self.push_ev(deliver_at, EV_DELIVER, i, dst);
                }
                let g = self.gen[i] as usize;
                self.push_ev(clocks.seconds()[i], EV_MIX, i, g);
            }
            CommAction::GlobalAverage => {
                if (k as u64) < self.barrier_epoch {
                    // The live population already averaged past this
                    // iteration while the node was crashed; it skips the
                    // resolved barrier and keeps catching up.
                    self.virt.as_mut().expect("virtual plane").missed_barriers += 1;
                    self.complete(i, target, clocks);
                } else {
                    self.state[i] = NodeState::Barrier;
                    self.barrier_waiting += 1;
                    if self.barrier_waiting == self.alive_count {
                        self.resolve_barrier_virtual(k, target, clocks);
                    }
                }
            }
        }
    }

    /// MIX: attempt the bounded-stale mix at the node's own clock.
    fn on_mix(&mut self, i: usize, target: usize, params: &mut ParamMatrix, clocks: &mut VirtualClocks) {
        let k = self.done[i];
        let round = self.round_ctr[i] % self.rounds;
        if self.deps_met(i, k, round) {
            self.do_mix(i, k, round, params);
            self.complete(i, target, clocks);
        } else {
            self.state[i] = NodeState::Waiting;
        }
    }

    fn on_mix_virtual(&mut self, i: usize, target: usize, clocks: &mut VirtualClocks) {
        let k = self.done[i];
        let round = self.round_ctr[i] % self.rounds;
        if self.deps_met(i, k, round) {
            self.do_mix_virtual(i, k, round);
            self.complete(i, target, clocks);
        } else {
            self.state[i] = NodeState::Waiting;
        }
    }

    /// DELIVER: complete one link traversal; a node stalled on the
    /// staleness bound resumes at the enabling delivery time (the stall is
    /// billed to its barrier-wait account).
    fn on_deliver(
        &mut self,
        src: usize,
        dst: usize,
        t: f64,
        target: usize,
        params: &mut ParamMatrix,
        clocks: &mut VirtualClocks,
    ) {
        let e = edge_index(&self.edges, src, dst);
        let l = &mut self.links[e];
        let msg = l.inflight.pop_front().expect("a delivery event has a queued message");
        debug_assert_eq!(msg.deliver_at.to_bits(), t.to_bits());
        // Occupancy accrues at traversal COMPLETION: in-flight time never
        // counts toward utilization, so busy_seconds <= elapsed time and
        // the utilization column stays within [0, 1].
        l.busy_seconds += msg.tx;
        if msg.version > l.cache_version {
            l.cache_version = msg.version;
            let old = std::mem::replace(&mut l.cache, msg.payload);
            self.store.release(old);
        } else {
            self.store.release(msg.payload);
        }
        if self.state[dst] == NodeState::Waiting {
            let k = self.done[dst];
            let round = self.round_ctr[dst] % self.rounds;
            if self.deps_met(dst, k, round) {
                clocks.stall_until(dst, t);
                self.do_mix(dst, k, round, params);
                self.complete(dst, target, clocks);
            }
        }
    }

    fn on_deliver_virtual(
        &mut self,
        src: usize,
        dst: usize,
        t: f64,
        target: usize,
        clocks: &mut VirtualClocks,
    ) {
        let e = edge_index(&self.edges, src, dst);
        let l = &mut self.links[e];
        let msg = l.inflight.pop_front().expect("a delivery event has a queued message");
        debug_assert_eq!(msg.deliver_at.to_bits(), t.to_bits());
        l.busy_seconds += msg.tx;
        // Deliveries complete even to (or from) crashed nodes — the
        // payload was already on the wire; versions dedupe duplicates
        // from a crash-redone iteration.
        if msg.version > l.cache_version {
            l.cache_version = msg.version;
            let old = std::mem::replace(&mut l.cache, msg.payload);
            self.store.release(old);
        } else {
            self.store.release(msg.payload);
        }
        self.try_resume(dst, t, target, clocks);
    }

    /// Resume a virtual node stalled on the staleness bound if its deps
    /// are now met (by a delivery, or by the blocking sender crashing).
    fn try_resume(&mut self, dst: usize, t: f64, target: usize, clocks: &mut VirtualClocks) {
        if !self.alive[dst] || self.state[dst] != NodeState::Waiting {
            return;
        }
        let k = self.done[dst];
        let round = self.round_ctr[dst] % self.rounds;
        if self.deps_met(dst, k, round) {
            clocks.stall_until(dst, t);
            self.do_mix_virtual(dst, k, round);
            self.complete(dst, target, clocks);
        }
    }

    /// Apply one scripted churn event (virtual plane only).
    fn on_churn(&mut self, idx: usize, t: f64, target: usize, clocks: &mut VirtualClocks) -> Result<()> {
        match self.churn[idx] {
            ChurnEvent::Crash { node, .. } => {
                if !self.alive[node] {
                    return Ok(()); // idempotent: already down
                }
                self.alive[node] = false;
                self.alive_count -= 1;
                self.gen[node] = self.gen[node].wrapping_add(1);
                if self.state[node] == NodeState::Barrier {
                    self.barrier_waiting -= 1;
                }
                self.state[node] = NodeState::Parked;
                self.virt.as_mut().expect("virtual plane").crashes += 1;
                ensure!(self.alive_count > 0, "churn script crashed every node by t = {t}");
                // The crash may satisfy a pending live-population barrier.
                if self.barrier_waiting > 0 && self.barrier_waiting == self.alive_count {
                    let k = (0..self.n)
                        .find(|&i| self.alive[i] && self.state[i] == NodeState::Barrier)
                        .map(|i| self.done[i])
                        .expect("a positive barrier count implies a live barrier node");
                    self.resolve_barrier_virtual(k, target, clocks);
                }
                // A crashed sender stops gating its receivers (deps_met
                // exempts it); wake any receiver it was blocking.
                for r in 0..self.rounds {
                    for x in 0..self.out_edges[r][node].len() {
                        let (dst, _) = self.out_edges[r][node][x];
                        self.try_resume(dst, t, target, clocks);
                    }
                }
            }
            ChurnEvent::Rejoin { node, .. } => {
                if self.alive[node] {
                    return Ok(()); // idempotent: already up
                }
                self.alive[node] = true;
                self.alive_count += 1;
                self.gen[node] = self.gen[node].wrapping_add(1);
                self.virt.as_mut().expect("virtual plane").rejoins += 1;
                // The offline span lands in the wait column so the
                // node-hours ledger still closes.
                clocks.stall_until(node, t);
                if self.done[node] < target {
                    self.schedule_ready(node, clocks.seconds()[node]);
                }
            }
            ChurnEvent::FlakyLink { src, dst, factor, .. } => {
                let e = edge_index(&self.edges, src, dst);
                self.links[e].tx_mult = factor;
                self.virt.as_mut().expect("virtual plane").link_events += 1;
            }
            ChurnEvent::LinkRestore { src, dst, .. } => {
                let e = edge_index(&self.edges, src, dst);
                self.links[e].tx_mult = 1.0;
                self.virt.as_mut().expect("virtual plane").link_events += 1;
            }
        }
        Ok(())
    }

    /// All nodes halted at the iteration-k global average: run the exact
    /// all-reduce, fire the sync hook, advance the clocks under the full
    /// barrier, release everyone.
    #[allow(clippy::too_many_arguments)]
    fn resolve_barrier(
        &mut self,
        k: usize,
        target: usize,
        params: &mut ParamMatrix,
        backend: &mut dyn CommBackend,
        pool: &WorkerPool,
        clocks: &mut VirtualClocks,
        sync_fn: &mut dyn FnMut(usize, &mut ParamMatrix) -> Result<()>,
    ) -> Result<()> {
        debug_assert!(self.done.iter().all(|&dn| dn == k));
        let charge = backend.global_average(params, pool)?;
        sync_fn(k, params)?;
        clocks.advance(&self.zeros, &charge.node_seconds, charge.barrier);
        self.barrier_waiting = 0;
        for i in 0..self.n {
            self.done[i] += 1;
            if self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            } else {
                self.state[i] = NodeState::Parked;
            }
        }
        Ok(())
    }

    /// The live population halted at the iteration-k global average: exact
    /// average over ALIVE nodes (ascending index — deterministic), billed
    /// as the all-reduce analog over m live members, with self-accounted
    /// traffic (ring all-reduce totals: `2 d (m-1)` scalars per node in
    /// `2 m (m-1)` chunked messages).
    fn resolve_barrier_virtual(&mut self, k: usize, target: usize, clocks: &mut VirtualClocks) {
        self.barrier_epoch = k as u64 + 1;
        let m = self.alive_count;
        debug_assert!(m > 0);
        debug_assert!(
            (0..self.n).filter(|&i| self.alive[i]).all(|i| self.done[i] == k),
            "live nodes drain at the same iteration before a barrier resolves"
        );
        {
            let virt = self.virt.as_mut().expect("virtual plane");
            if virt.surrogate {
                let mut sm = 0.0f64;
                let mut sv = 0.0f64;
                for i in 0..self.n {
                    if self.alive[i] {
                        sm += virt.smean[i];
                        sv += virt.svar[i];
                    }
                }
                let mean = sm / m as f64;
                let var = sv / (m as f64 * m as f64);
                for i in 0..self.n {
                    if self.alive[i] {
                        virt.smean[i] = mean;
                        virt.svar[i] = var;
                    }
                }
            } else {
                let d = virt.state.d();
                let mut avg = vec![0.0f32; d];
                for i in 0..self.n {
                    if self.alive[i] {
                        for (a, v) in avg.iter_mut().zip(virt.state.row(i)) {
                            *a += v;
                        }
                    }
                }
                let inv = 1.0 / m as f32;
                for a in avg.iter_mut() {
                    *a *= inv;
                }
                for i in 0..self.n {
                    if self.alive[i] {
                        virt.state.copy_row_from(i, &avg);
                    }
                }
            }
            virt.stats.scalars_sent += 2 * self.cost_dim as u64 * (m as u64 - 1);
            virt.stats.msgs += 2 * (m as u64) * (m as u64 - 1);
        }
        // Billing: everyone stalls to the slowest live member (the wait
        // lands in the barrier-wait column), then pays the per-node
        // all-reduce charge over m members.
        let start = (0..self.n)
            .filter(|&i| self.alive[i])
            .map(|i| clocks.seconds()[i])
            .fold(f64::NEG_INFINITY, f64::max);
        let mut crit = 0.0f64;
        for i in 0..self.n {
            if self.alive[i] {
                let c = 2.0 * self.theta[i] * self.cost_dim as f64 + m as f64 * self.alpha[i];
                crit = crit.max(c);
                clocks.stall_until(i, start);
                clocks.advance_one(i, c);
            }
        }
        self.virt.as_mut().expect("virtual plane").stats.sim_seconds += crit;
        let end = (0..self.n)
            .filter(|&i| self.alive[i])
            .map(|i| clocks.seconds()[i])
            .fold(f64::NEG_INFINITY, f64::max);
        self.record(EV_READY, 0, self.n, k, end);
        self.barrier_waiting = 0;
        for i in 0..self.n {
            if !self.alive[i] {
                continue;
            }
            self.done[i] += 1;
            if self.done[i] < target {
                self.schedule_ready(i, clocks.seconds()[i]);
            } else {
                self.state[i] = NodeState::Parked;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommBackend, Compression, SharedBackend};
    use crate::costmodel::CostModel;
    use crate::rng::Rng;

    /// Deterministic synthetic local update: pure in (node, iter), so any
    /// execution order produces the same bits.
    fn fake_step(params: &mut ParamMatrix, batch: &[(usize, usize)]) -> Result<()> {
        for &(node, iter) in batch {
            let mut r = Rng::new(0xFEED ^ ((node as u64) << 32) ^ iter as u64);
            for x in params.row_mut(node) {
                *x = 0.9 * *x + 0.1 * r.normal() as f32;
            }
        }
        Ok(())
    }

    fn engine_run(
        topo: &Topology,
        costs: &NodeCosts,
        d: usize,
        s: usize,
        kind: AlgorithmKind,
        h: usize,
        steps: usize,
    ) -> (ParamMatrix, VirtualClocks, AsyncGossip) {
        let mut params = ParamMatrix::random(&mut Rng::new(5), topo.n, d, 1.0);
        let mut engine =
            AsyncGossip::new(topo, costs, d, 1000, s, kind, h, &params).unwrap();
        let mut backend = SharedBackend::new(topo, d, costs, 1000, Compression::None);
        let pool = WorkerPool::new(1);
        let mut clocks = VirtualClocks::new(topo);
        let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
        let mut sync = |_k: usize, _p: &mut ParamMatrix| -> Result<()> { Ok(()) };
        for t in 1..=steps {
            engine
                .run_until(t, &mut params, &mut backend, &pool, &mut clocks, costs, &mut step, &mut sync)
                .unwrap();
        }
        (params, clocks, engine)
    }

    #[test]
    fn strict_mode_matches_bsp_replay_bitwise() {
        let d = 17;
        for topo in [Topology::ring(6), Topology::one_peer_expo(8)] {
            let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), topo.n);
            let (ev_params, ev_clocks, _) =
                engine_run(&topo, &costs, d, 0, AlgorithmKind::GossipPga, 4, 11);
            // BSP reference: same updates, backend-level gossip, same billing.
            let mut params = ParamMatrix::random(&mut Rng::new(5), topo.n, d, 1.0);
            let mut backend = SharedBackend::new(&topo, d, &costs, 1000, Compression::None);
            let pool = WorkerPool::new(1);
            let mut clocks = VirtualClocks::new(&topo);
            for k in 0..11 {
                let batch: Vec<(usize, usize)> = (0..topo.n).map(|i| (i, k)).collect();
                fake_step(&mut params, &batch).unwrap();
                if (k + 1) % 4 == 0 {
                    let c = backend.global_average(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                } else {
                    let c = backend.gossip(&mut params, &pool).unwrap();
                    clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
                }
            }
            assert_eq!(ev_params, params, "{:?}", topo.kind);
            assert_eq!(ev_clocks.seconds(), clocks.seconds(), "{:?}", topo.kind);
        }
    }

    #[test]
    fn relaxed_mode_respects_staleness_bound_and_runs_dry() {
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6)
            .with_straggler(0, 4.0)
            .unwrap();
        for s in [1usize, 3] {
            let (_, clocks, engine) =
                engine_run(&topo, &costs, 9, s, AlgorithmKind::Gossip, usize::MAX, 20);
            let (max, mean) = engine.staleness();
            assert!(max as usize <= s, "staleness {max} exceeded the bound {s}");
            assert!(mean >= 0.0);
            assert!(clocks.max_seconds() > 0.0);
            assert!(engine.link_utilization(clocks.max_seconds()) > 0.0);
        }
    }

    #[test]
    fn async_critical_path_beats_barrier_billing_under_straggler() {
        // The per-link overlap story at unit scale: with a 4x straggler on
        // a ring, the event plane's critical path undercuts the
        // neighborhood-barrier bill (which exposes every transfer).
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6)
            .with_straggler(0, 4.0)
            .unwrap();
        let steps = 16;
        let (_, ev_clocks, _) =
            engine_run(&topo, &costs, 9, 2, AlgorithmKind::Gossip, usize::MAX, steps);
        let mut clocks = VirtualClocks::new(&topo);
        let mut backend = SharedBackend::new(&topo, 9, &costs, 1000, Compression::None);
        let pool = WorkerPool::new(1);
        let mut params = ParamMatrix::random(&mut Rng::new(5), 6, 9, 1.0);
        for _ in 0..steps {
            let c = backend.gossip(&mut params, &pool).unwrap();
            clocks.advance(&costs.compute, &c.node_seconds, c.barrier);
        }
        assert!(
            ev_clocks.max_seconds() < clocks.max_seconds(),
            "async {} !< barrier {}",
            ev_clocks.max_seconds(),
            clocks.max_seconds()
        );
    }

    #[test]
    fn export_import_roundtrips_and_validates() {
        let topo = Topology::ring(5);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 5)
            .with_straggler(1, 3.0)
            .unwrap();
        let (params, _, engine) =
            engine_run(&topo, &costs, 7, 2, AlgorithmKind::Gossip, usize::MAX, 9);
        let st = engine.export_state();
        let mut fresh =
            AsyncGossip::new(&topo, &costs, 7, 1000, 2, AlgorithmKind::Gossip, usize::MAX, &params)
                .unwrap();
        fresh.import_state(&st, 9, 9).unwrap();
        assert_eq!(fresh.export_state(), st);
        // Mismatched staleness bound is rejected.
        let mut wrong =
            AsyncGossip::new(&topo, &costs, 7, 1000, 1, AlgorithmKind::Gossip, usize::MAX, &params)
                .unwrap();
        assert!(wrong.import_state(&st, 9, 9).is_err());
    }

    #[test]
    fn regime_names_roundtrip() {
        for r in [Regime::Bsp, Regime::Overlap, Regime::Async] {
            assert_eq!(Regime::from_name(r.name()).unwrap(), r);
        }
        assert!(Regime::from_name("warp").is_err());
        assert_eq!(Regime::default(), Regime::Bsp);
    }

    #[test]
    fn aga_is_rejected() {
        let topo = Topology::ring(4);
        let costs = NodeCosts::homogeneous(CostModel::generic(), 4);
        let init = ParamMatrix::zeros(4, 3);
        assert!(
            AsyncGossip::new(&topo, &costs, 3, 100, 1, AlgorithmKind::GossipAga, 8, &init).is_err()
        );
    }

    #[test]
    fn pooling_is_transparent_to_the_engine_bits() {
        // intern on (one slot per pushed iterate) vs off (PR 5 shape: one
        // slot per link) — identical params, clocks, and staleness.
        let topo = Topology::one_peer_expo(8);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8)
            .with_straggler(2, 3.0)
            .unwrap();
        let mut outs = Vec::new();
        for intern in [true, false] {
            let mut params = ParamMatrix::random(&mut Rng::new(5), 8, 11, 1.0);
            let mut engine = AsyncGossip::new_with_storage(
                &topo, &costs, 11, 1000, 2, AlgorithmKind::GossipPga, 4, &params, intern,
            )
            .unwrap();
            engine.enable_trace();
            let mut backend = SharedBackend::new(&topo, 11, &costs, 1000, Compression::None);
            let pool = WorkerPool::new(1);
            let mut clocks = VirtualClocks::new(&topo);
            let mut step = |p: &mut ParamMatrix, b: &[(usize, usize)]| fake_step(p, b);
            let mut sync = |_k: usize, _p: &mut ParamMatrix| -> Result<()> { Ok(()) };
            engine
                .run_until(13, &mut params, &mut backend, &pool, &mut clocks, &costs, &mut step, &mut sync)
                .unwrap();
            let trace = engine.trace().unwrap().to_vec();
            outs.push((params, clocks.seconds().to_vec(), trace, engine.staleness()));
        }
        assert_eq!(outs[0], outs[1], "payload pooling changed engine bits");
    }

    #[test]
    fn virtual_surrogate_plane_runs_and_accounts() {
        let topo = Topology::one_peer_expo(8);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 8);
        let cfg = VirtualConfig { dim: 0, seed: 42, churn: Vec::new(), regions: None };
        let mut eng =
            AsyncGossip::new_virtual(&topo, &costs, 25_500_000, 2, AlgorithmKind::GossipPga, 4, cfg)
                .unwrap();
        let mut clocks = VirtualClocks::flat(8);
        eng.run_virtual_until(9, &mut clocks).unwrap();
        assert!(eng.is_virtual());
        assert_eq!(eng.min_alive_done(), 9);
        assert_eq!(eng.alive_count(), 8);
        let st = eng.virt_stats();
        assert!(st.scalars_sent > 0 && st.msgs > 0 && st.sim_seconds > 0.0);
        // The audit the 10^5 suite runs at scale, exercised here in-module:
        // surrogate mode allocates NO dense scalar, ever.
        assert_eq!(eng.store().peak_dense_scalars(), 0);
        assert!(eng.store().peak_live_slots() <= eng.num_links());
        assert!(clocks.max_seconds() > 0.0);
        let means = eng.virt_means().unwrap();
        assert!(means.iter().all(|m| m.is_finite()));
        // Gossip + two PGA barriers (k=3, k=7) pull the population toward
        // consensus: the spread must shrink from its initial N(0,1) draw.
        let spread = |xs: &[f64]| {
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64
        };
        let mut r0 = Rng::new(42);
        let init: Vec<f64> = (0..8).map(|_| r0.normal()).collect();
        assert!(spread(means) < spread(&init), "gossip + PGA must tighten consensus");
        assert!(eng.virt_vars().unwrap().iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn virtual_dense_plane_runs_and_pools() {
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_bert(), 6);
        let cfg = VirtualConfig { dim: 4, seed: 7, churn: Vec::new(), regions: None };
        let mut eng =
            AsyncGossip::new_virtual(&topo, &costs, 1000, 1, AlgorithmKind::Gossip, usize::MAX, cfg)
                .unwrap();
        let mut clocks = VirtualClocks::flat(6);
        eng.run_virtual_until(5, &mut clocks).unwrap();
        let state = eng.virt_dense().unwrap();
        assert_eq!((state.n(), state.d()), (6, 4));
        assert!(state.as_slice().iter().all(|v| v.is_finite()));
        // Dense virtual payloads pool by (src, version): peak live dense
        // scalars stay well below the per-edge copy cost (12 links x 4).
        assert!(eng.store().peak_dense_scalars() < eng.num_links() * 4);
    }

    #[test]
    fn churn_crash_rejoin_flaky_replays_bit_exactly() {
        fn run() -> (Vec<TraceEv>, Vec<f64>, CommStats, (u64, u64, u64, u64), Vec<f64>) {
            let topo = Topology::ring(6);
            let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6);
            let churn = vec![
                ChurnEvent::FlakyLink { at: 0.05, src: 1, dst: 2, factor: 6.0 },
                ChurnEvent::Crash { at: 0.4, node: 3 },
                ChurnEvent::Rejoin { at: 1.1, node: 3 },
                ChurnEvent::LinkRestore { at: 1.3, src: 1, dst: 2 },
            ];
            let cfg = VirtualConfig { dim: 0, seed: 99, churn, regions: None };
            let mut eng = AsyncGossip::new_virtual(
                &topo, &costs, 1_000_000, 2, AlgorithmKind::GossipPga, 4, cfg,
            )
            .unwrap();
            eng.enable_trace();
            let mut clocks = VirtualClocks::flat(6);
            // Chunked drive — replays must chunk identically to compare.
            for t in [3usize, 8, 12] {
                eng.run_virtual_until(t, &mut clocks).unwrap();
            }
            (
                eng.trace().unwrap().to_vec(),
                clocks.seconds().to_vec(),
                eng.virt_stats(),
                eng.churn_counts(),
                eng.virt_means().unwrap().to_vec(),
            )
        }
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0, "event order must replay bit-exactly");
        assert_eq!(
            a.1.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            b.1.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.2, b.2);
        assert_eq!(a.3, b.3);
        assert_eq!((a.3 .0, a.3 .1, a.3 .2), (1u64, 1u64, 2u64));
        assert_eq!(
            a.4.iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
            b.4.iter().map(|m| m.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn churn_scripts_are_validated_up_front() {
        let topo = Topology::ring(4);
        let costs = NodeCosts::homogeneous(CostModel::generic(), 4);
        let bad = [
            vec![ChurnEvent::Crash { at: 1.0, node: 9 }],
            vec![ChurnEvent::Rejoin { at: -1.0, node: 1 }],
            vec![ChurnEvent::FlakyLink { at: 0.5, src: 0, dst: 2, factor: 2.0 }], // not an edge
            vec![ChurnEvent::FlakyLink { at: 0.5, src: 0, dst: 1, factor: 0.0 }],
            vec![ChurnEvent::LinkRestore { at: 0.5, src: 7, dst: 1 }],
        ];
        for churn in bad {
            let cfg = VirtualConfig { dim: 0, seed: 1, churn, regions: None };
            assert!(
                AsyncGossip::new_virtual(
                    &topo, &costs, 100, 1, AlgorithmKind::Gossip, usize::MAX, cfg
                )
                .is_err()
            );
        }
    }

    #[test]
    fn region_tiers_slow_cross_region_links() {
        // Two tiers, 10x inter-region latency: the same schedule takes
        // strictly longer than the single-region run.
        let topo = Topology::ring(6);
        let costs = NodeCosts::homogeneous(CostModel::calibrated_resnet50(), 6);
        let mut finish = Vec::new();
        for regions in [None, Some(RegionMap::tiers(6, 2, 1.0, 10.0).unwrap())] {
            let cfg = VirtualConfig { dim: 0, seed: 5, churn: Vec::new(), regions };
            let mut eng = AsyncGossip::new_virtual(
                &topo, &costs, 25_500_000, 1, AlgorithmKind::Gossip, usize::MAX, cfg,
            )
            .unwrap();
            let mut clocks = VirtualClocks::flat(6);
            eng.run_virtual_until(8, &mut clocks).unwrap();
            finish.push(clocks.max_seconds());
        }
        assert!(
            finish[1] > finish[0],
            "10x inter-region links must stretch the critical path ({} !> {})",
            finish[1],
            finish[0]
        );
    }
}
