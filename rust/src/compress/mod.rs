//! Gossip-message compression (the paper's §2 "orthogonal techniques":
//! quantization (Alistarh et al. 2017) and sparsification (Koloskova et al.
//! 2019) "can be added to our methods" — this module adds them).
//!
//! A [`Codec`] transforms the parameter vector a node *transmits* during
//! gossip; the receiver mixes the decoded message. Error feedback keeps a
//! per-node residual so the compression error is re-injected the next round
//! (the standard EF-SGD trick that preserves convergence).
//!
//! Codecs:
//! * [`Identity`] — no-op baseline.
//! * [`TopK`] — keep the k largest-magnitude coordinates.
//! * [`Int8`] — per-block linear quantization to i8 (4x compression).
//!
//! The ablation bench `abl_compression` measures the accuracy/traffic
//! trade-off of gossip compression under Gossip-PGA.

/// A compressed message plus its on-wire size.
#[derive(Clone, Debug)]
pub struct Compressed {
    /// Decoded (dense) view — the simulator mixes dense vectors; the wire
    /// size is tracked separately so traffic accounting stays honest.
    pub dense: Vec<f32>,
    /// Bytes this message would occupy on the wire.
    pub wire_bytes: usize,
}

/// A lossy message transform with explicit wire cost.
pub trait Codec: Send {
    /// Compress `x`; returns the receiver-visible dense vector + wire size.
    fn compress(&self, x: &[f32]) -> Compressed;
    fn name(&self) -> &'static str;
}

/// Boxed codecs are codecs too (the comm backends store per-node
/// `ErrorFeedback<Box<dyn Codec>>` chosen at config time).
impl<C: Codec + ?Sized> Codec for Box<C> {
    fn compress(&self, x: &[f32]) -> Compressed {
        (**self).compress(x)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// No compression.
pub struct Identity;

impl Codec for Identity {
    fn compress(&self, x: &[f32]) -> Compressed {
        Compressed { dense: x.to_vec(), wire_bytes: x.len() * 4 }
    }
    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Top-k magnitude sparsification. Wire format: k (index, value) pairs.
pub struct TopK {
    /// Fraction of coordinates kept, in (0, 1].
    pub frac: f64,
}

impl Codec for TopK {
    fn compress(&self, x: &[f32]) -> Compressed {
        let d = x.len();
        let k = ((d as f64 * self.frac).ceil() as usize).clamp(1, d);
        // Select the k largest |x_i| via a partial sort of indices.
        let mut idx: Vec<u32> = (0..d as u32).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut dense = vec![0.0f32; d];
        for &i in &idx[..k] {
            dense[i as usize] = x[i as usize];
        }
        Compressed { dense, wire_bytes: k * 8 } // 4B index + 4B value
    }
    fn name(&self) -> &'static str {
        "topk"
    }
}

/// Per-block int8 linear quantization: each `block` of coordinates shares a
/// f32 scale = max|x| / 127.
pub struct Int8 {
    pub block: usize,
}

impl Default for Int8 {
    fn default() -> Self {
        Int8 { block: 1024 }
    }
}

impl Codec for Int8 {
    fn compress(&self, x: &[f32]) -> Compressed {
        let mut dense = Vec::with_capacity(x.len());
        let mut blocks = 0usize;
        for chunk in x.chunks(self.block.max(1)) {
            blocks += 1;
            let maxabs = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
            for &v in chunk {
                let q = (v / scale).round().clamp(-127.0, 127.0) as i8;
                dense.push(q as f32 * scale);
            }
        }
        Compressed { dense, wire_bytes: x.len() + blocks * 4 }
    }
    fn name(&self) -> &'static str {
        "int8"
    }
}

/// Error-feedback wrapper: residual r accumulates what compression dropped
/// and is added back before the next compression (EF-SGD; Karimireddy et
/// al. 2019). One instance per sending node.
pub struct ErrorFeedback<C: Codec> {
    codec: C,
    residual: Vec<f32>,
}

impl<C: Codec> ErrorFeedback<C> {
    pub fn new(codec: C, d: usize) -> Self {
        ErrorFeedback { codec, residual: vec![0.0; d] }
    }

    /// Compress `x + residual`, update the residual with what was lost.
    pub fn compress(&mut self, x: &[f32]) -> Compressed {
        debug_assert_eq!(x.len(), self.residual.len());
        let corrected: Vec<f32> = x.iter().zip(&self.residual).map(|(a, r)| a + r).collect();
        let out = self.codec.compress(&corrected);
        for ((r, c), o) in self.residual.iter_mut().zip(&corrected).zip(&out.dense) {
            *r = c - o;
        }
        out
    }

    /// The accumulated compression error (checkpointable state — a resumed
    /// run must re-inject exactly what the interrupted one was carrying).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Overwrite the residual (checkpoint restore).
    pub fn set_residual(&mut self, r: &[f32]) {
        assert_eq!(r.len(), self.residual.len(), "residual length mismatch");
        self.residual.copy_from_slice(r);
    }

    /// Zero the residual (fresh-start semantics for pre-v3 checkpoints).
    pub fn reset_residual(&mut self) {
        self.residual.fill(0.0);
    }

    pub fn name(&self) -> &'static str {
        self.codec.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn l2(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
    }

    #[test]
    fn identity_roundtrip_exact() {
        let x = vec![1.0, -2.0, 3.5];
        let c = Identity.compress(&x);
        assert_eq!(c.dense, x);
        assert_eq!(c.wire_bytes, 12);
    }

    #[test]
    fn topk_keeps_largest() {
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.05];
        let c = TopK { frac: 0.4 }.compress(&x); // k = 2
        assert_eq!(c.dense, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
        assert_eq!(c.wire_bytes, 16);
    }

    #[test]
    fn topk_full_fraction_is_lossless() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(100, 1.0);
        let c = TopK { frac: 1.0 }.compress(&x);
        assert_eq!(c.dense, x);
    }

    #[test]
    fn int8_error_bounded_by_half_scale() {
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(5000, 3.0);
        let c = Int8::default().compress(&x);
        for (chunk, qchunk) in x.chunks(1024).zip(c.dense.chunks(1024)) {
            let maxabs = chunk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let half_scale = maxabs / 127.0 / 2.0 + 1e-7;
            for (a, b) in chunk.iter().zip(qchunk) {
                assert!((a - b).abs() <= half_scale * 1.01, "{a} vs {b}");
            }
        }
        // 4x compression (+ scales).
        assert!(c.wire_bytes < 5000 * 4 / 3);
    }

    #[test]
    fn int8_zero_block_safe() {
        let x = vec![0.0f32; 10];
        let c = Int8 { block: 4 }.compress(&x);
        assert_eq!(c.dense, x);
    }

    #[test]
    fn error_feedback_reinjects_dropped_mass() {
        // With aggressive top-k, EF must eventually transmit every coord:
        // compressing a CONSTANT vector repeatedly, the cumulative
        // transmitted mass approaches k_effective * rounds * value.
        let d = 8;
        let x = vec![1.0f32; d];
        let mut ef = ErrorFeedback::new(TopK { frac: 0.25 }, d); // k = 2
        let mut transmitted = vec![0.0f32; d];
        for _ in 0..8 {
            let c = ef.compress(&x);
            for (t, v) in transmitted.iter_mut().zip(&c.dense) {
                *t += v;
            }
        }
        // every coordinate must have been sent at least once
        assert!(transmitted.iter().all(|&t| t > 0.0), "{transmitted:?}");
    }

    #[test]
    fn error_feedback_reduces_long_run_error() {
        // Average of EF-compressed messages converges to the true vector;
        // without EF the bias persists.
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(64, 1.0);
        let mut ef = ErrorFeedback::new(TopK { frac: 0.1 }, 64);
        let rounds = 50;
        let mut acc_ef = vec![0.0f32; 64];
        let mut acc_plain = vec![0.0f32; 64];
        let plain = TopK { frac: 0.1 };
        for _ in 0..rounds {
            for (a, v) in acc_ef.iter_mut().zip(ef.compress(&x).dense) {
                *a += v / rounds as f32;
            }
            for (a, v) in acc_plain.iter_mut().zip(plain.compress(&x).dense) {
                *a += v / rounds as f32;
            }
        }
        assert!(l2(&acc_ef, &x) < 0.5 * l2(&acc_plain, &x), "{} vs {}", l2(&acc_ef, &x), l2(&acc_plain, &x));
    }

    #[test]
    fn wire_bytes_orderings() {
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(4096, 1.0);
        let full = Identity.compress(&x).wire_bytes;
        let tk = TopK { frac: 0.1 }.compress(&x).wire_bytes;
        let q8 = Int8::default().compress(&x).wire_bytes;
        assert!(tk < q8 && q8 < full, "{tk} {q8} {full}");
    }
}
