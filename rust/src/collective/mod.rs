//! In-process collective-communication substrate.
//!
//! The paper's cluster (NCCL over 25 Gbps TCP) is replaced by a real
//! message-passing layer over `std::sync::mpsc` channels: each node owns an
//! [`Endpoint`] and communicates only by `send`/`recv`, exactly like a
//! socket-based worker would. On top of the bus we implement the two
//! primitives Algorithm 1 needs:
//!
//! * [`gossip_exchange`] — every node sends its vector to its out-neighbors
//!   and mixes what it receives with its weight row (the gossip branch);
//! * [`ring_all_reduce`] — bandwidth-optimal ring all-reduce
//!   (reduce-scatter + all-gather, 2(n-1) chunked steps), the paper's
//!   global-averaging primitive (§3, "All-Reduce v.s. multiple Gossips").
//!
//! Every endpoint counts wire scalars and messages so the Table 17 bench —
//! and, since the unified CommPlane ([`crate::comm`]), every *training run*
//! on the bus backend — can report measured traffic next to the alpha-beta
//! model's predictions.
//!
//! §Sparse setup: an endpoint holds sender channels only for the edges it
//! was built with ([`bus_for`]); a ring of 10 000 nodes allocates 2 senders
//! per node, not 9 999. [`bus`] remains the fully-connected convenience for
//! the all-to-all cases. A node's receive channel closes once every
//! in-neighbor's endpoint drops, which is what turns a crashed peer into a
//! clean `Err` instead of a deadlock (see
//! `node_failure_surfaces_as_error_not_hang`).
//!
//! §Deadlines: a peer that *wedges* — alive, channel open, transmitting
//! nothing — used to park its receivers forever ([`Endpoint::recv_from`]
//! had only the Disconnected exit). Every endpoint now carries an optional
//! receive deadline ([`Endpoint::set_recv_deadline`]): a stalled peer
//! surfaces as a typed [`RecvTimeout`] naming the silent node, which the
//! round state machine ([`crate::coordinator::rounds`]) converts into a
//! membership drop instead of a hang. Messages are epoch-tagged so a round
//! retried after a drop discards the aborted round's half-delivered frames.
//!
//! §Transports: the [`Wire`] trait is the transport contract the generic
//! message-passing backend ([`crate::comm::BusCore`]) is written against;
//! [`Endpoint`] (mpsc channels) and [`tcp::TcpEndpoint`] (length-prefixed
//! frames over real loopback sockets) both implement it, which is what
//! makes the TCP backend's uncompressed trajectories bit-identical to the
//! bus's: same phase code, same kernel, different bytes underneath.

pub mod tcp;

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

/// A tagged message: (source, round epoch, payload). The epoch is stamped
/// by the sender and filtered by the receiver so a round retried after a
/// peer drop never mixes the aborted attempt's half-delivered frames.
pub type Msg = (usize, u32, Vec<f32>);

/// The typed error a deadline-armed receive returns when a peer stays
/// silent: the waiting node, the silent node, and how long it waited.
/// The worker pool flattens job errors to rendered strings, so
/// [`stalled_peer`] recovers the peer index from the message text too.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecvTimeout {
    pub waiter: usize,
    pub from: usize,
    pub waited: Duration,
}

impl std::fmt::Display for RecvTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "node {}: no message from stalled peer {} within {:?}",
            self.waiter, self.from, self.waited
        )
    }
}

impl std::error::Error for RecvTimeout {}

/// Recover the silent peer's index from a rendered [`RecvTimeout`] message
/// (possibly wrapped in a pool-job / anyhow context chain). `None` for any
/// other error text — callers must treat those as real failures.
pub fn stalled_peer(text: &str) -> Option<usize> {
    let marker = "no message from stalled peer ";
    let at = text.find(marker)? + marker.len();
    let digits: &str =
        &text[at..at + text[at..].chars().take_while(|c| c.is_ascii_digit()).count()];
    digits.parse().ok()
}

/// The transport contract shared by the mpsc [`Endpoint`] and the framed
/// [`tcp::TcpEndpoint`]: rank-addressed billed sends, source-selective
/// receives with parking, an optional stalled-peer deadline, and epoch
/// tagging for clean round retries. [`crate::comm::BusCore`] is generic
/// over this, so every transport runs the exact same collective phases.
/// (`'static` because the overlapped gossip path shards endpoint chunks
/// into pool jobs that outlive the issuing call's borrows.)
pub trait Wire: Send + 'static {
    fn rank(&self) -> usize;
    /// Out-routes currently held (regression tests count these to pin the
    /// lazy-edge contract).
    fn degree(&self) -> usize;
    /// Cumulative traffic: (wire scalars billed, messages sent).
    fn traffic(&self) -> (u64, u64);
    fn send_billed(&mut self, to: usize, payload: Vec<f32>, wire_scalars: u64) -> Result<()>;
    fn send(&mut self, to: usize, payload: Vec<f32>) -> Result<()> {
        let wire = payload.len() as u64;
        self.send_billed(to, payload, wire)
    }
    fn recv_from(&mut self, from: usize) -> Result<Vec<f32>>;
    /// Arm (`Some`) or disarm (`None`) the per-receive stalled-peer
    /// deadline. Disarmed receives block until a message or a hangup —
    /// the pre-deadline behavior, bit for bit.
    fn set_recv_deadline(&mut self, deadline: Option<Duration>);
    /// Enter round `epoch`: parked frames are cleared and in-flight frames
    /// from older epochs are discarded on receipt.
    fn reset_epoch(&mut self, epoch: u32);
    /// Re-tag without clearing: subsequent sends stamp `epoch` and receives
    /// require it, but frames already queued or parked survive. This is the
    /// overlapped-gossip stamp — a send job advances its endpoint to the
    /// issued round's tag while legitimate frames for that very round may
    /// already sit in the inbox (delivered by a peer's earlier-running send
    /// job), so a clearing reset would destroy live data.
    fn set_epoch(&mut self, epoch: u32);
    /// Cumulative count of frames discarded on receipt because their epoch
    /// tag did not match the receiver's current round — the droppings of
    /// aborted or already-drained rounds. Feeds `CommStats::stale_frames_dropped`.
    fn stale_drops(&self) -> u64;
}

/// Deadline-aware tagged receive shared by both transports: park
/// out-of-order arrivals, discard stale-epoch frames (counting each one
/// into `stale`), and surface a stalled peer as a typed [`RecvTimeout`]
/// instead of blocking forever.
pub(crate) fn recv_tagged(
    rank: usize,
    receiver: &Receiver<Msg>,
    parked: &mut Vec<Msg>,
    epoch: u32,
    deadline: Option<Duration>,
    from: usize,
    stale: &mut u64,
) -> Result<Vec<f32>> {
    if let Some(pos) = parked.iter().position(|(src, e, _)| *src == from && *e == epoch) {
        return Ok(parked.remove(pos).2);
    }
    let limit = deadline.map(|dl| (Instant::now() + dl, dl));
    loop {
        let (src, e, payload) = match limit {
            None => receiver.recv().map_err(|_| anyhow!("bus closed waiting for {from}"))?,
            Some((at, dl)) => {
                match receiver.recv_timeout(at.saturating_duration_since(Instant::now())) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => {
                        return Err(anyhow::Error::new(RecvTimeout {
                            waiter: rank,
                            from,
                            waited: dl,
                        }));
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("bus closed waiting for {from}"));
                    }
                }
            }
        };
        if e != epoch {
            *stale += 1;
            continue; // a dropped or already-drained round's leftover frame
        }
        if src == from {
            return Ok(payload);
        }
        parked.push((src, e, payload));
    }
}

/// Per-node communication endpoint on the in-proc bus.
pub struct Endpoint {
    pub rank: usize,
    pub n: usize,
    /// Outgoing channels, sorted by target rank; only the edges this bus
    /// was built with exist (no self edge — a node never holds its own
    /// sender, so its receiver closes when all in-neighbors drop).
    senders: Vec<(usize, Sender<Msg>)>,
    receiver: Receiver<Msg>,
    /// Out-of-order arrivals parked until requested.
    parked: Vec<Msg>,
    /// Round epoch stamped on every send and required of every receive.
    epoch: u32,
    /// Optional stalled-peer deadline; `None` (the default) blocks forever
    /// exactly like the pre-deadline endpoint.
    recv_deadline: Option<Duration>,
    /// Traffic accounting: wire scalars (f32-equivalents billed per send)
    /// and message count.
    pub scalars_sent: u64,
    pub msgs_sent: u64,
    /// Frames discarded on receipt for carrying a stale epoch tag.
    pub stale_drops: u64,
}

/// Build a fully-connected bus of `n` endpoints (all-to-all edges).
pub fn bus(n: usize) -> Vec<Endpoint> {
    let full: Vec<Vec<usize>> =
        (0..n).map(|i| (0..n).filter(|&j| j != i).collect()).collect();
    bus_for(n, &full)
}

/// Build a bus with exactly the sender channels in `out_edges`
/// (`out_edges[i]` = the targets node i can send to; self entries are
/// ignored, duplicates deduplicated). Sparse topologies pay O(edges) setup
/// instead of the old fully-connected O(n^2) sender table.
pub fn bus_for(n: usize, out_edges: &[Vec<usize>]) -> Vec<Endpoint> {
    bus_with_handles(n, out_edges).0
}

/// [`bus_for`], but also returning the raw inbox senders in rank order so
/// a caller can wire **additional** edges later via
/// [`Endpoint::add_sender`] — the lazy-edge hook the bus backend uses to
/// defer its all-to-all chunk-exchange table until the first
/// `global_average` actually needs it. Dropping the handles restores the
/// exact hangup semantics of [`bus_for`] (a node's receiver closes when
/// all in-neighbors drop).
pub fn bus_with_handles(n: usize, out_edges: &[Vec<usize>]) -> (Vec<Endpoint>, Vec<Sender<Msg>>) {
    assert_eq!(out_edges.len(), n, "one edge list per node");
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }
    let endpoints = rxs
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| {
            let mut targets: Vec<usize> =
                out_edges[rank].iter().copied().filter(|&j| j != rank).collect();
            targets.sort_unstable();
            targets.dedup();
            Endpoint {
                rank,
                n,
                senders: targets
                    .into_iter()
                    .map(|j| {
                        assert!(j < n, "edge {rank}->{j} out of range for n={n}");
                        (j, txs[j].clone())
                    })
                    .collect(),
                receiver,
                parked: Vec::new(),
                epoch: 0,
                recv_deadline: None,
                scalars_sent: 0,
                msgs_sent: 0,
                stale_drops: 0,
            }
        })
        .collect();
    (endpoints, txs)
}

impl Endpoint {
    /// Send `payload` to node `to`, billing its dense length on the wire.
    pub fn send(&mut self, to: usize, payload: Vec<f32>) -> Result<()> {
        let wire = payload.len() as u64;
        self.send_billed(to, payload, wire)
    }

    /// Send `payload` to node `to`, billing `wire_scalars` f32-equivalents
    /// (the compressed-gossip path ships the dense vector the simulator
    /// mixes but charges the codec's wire size, keeping traffic accounting
    /// honest — see [`crate::compress::Compressed::wire_bytes`]).
    pub fn send_billed(&mut self, to: usize, payload: Vec<f32>, wire_scalars: u64) -> Result<()> {
        let idx = self
            .senders
            .binary_search_by_key(&to, |(j, _)| *j)
            .map_err(|_| anyhow!("node {} has no channel to node {to}", self.rank))?;
        // Count only delivered messages: a refused or hung-up send is not
        // traffic (tests assert both failure paths leave counters alone).
        self.senders[idx]
            .1
            .send((self.rank, self.epoch, payload))
            .map_err(|_| anyhow!("node {to} hung up"))?;
        self.scalars_sent += wire_scalars;
        self.msgs_sent += 1;
        Ok(())
    }

    /// Receive the next message from node `from` (parking others). With a
    /// deadline armed, a silent `from` yields a typed [`RecvTimeout`]
    /// instead of parking this thread forever.
    pub fn recv_from(&mut self, from: usize) -> Result<Vec<f32>> {
        recv_tagged(
            self.rank,
            &self.receiver,
            &mut self.parked,
            self.epoch,
            self.recv_deadline,
            from,
            &mut self.stale_drops,
        )
    }

    /// Arm (`Some`) or disarm (`None`) the stalled-peer receive deadline.
    pub fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.recv_deadline = deadline;
    }

    /// Enter round `epoch`; parked frames and already-queued older-epoch
    /// frames are discarded (in-flight stragglers are filtered on receipt).
    pub fn reset_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
        self.parked.clear();
        while self.receiver.try_recv().is_ok() {}
    }

    /// Re-tag without clearing (see [`Wire::set_epoch`]): queued and parked
    /// frames survive; mismatched tags are filtered (and counted) on receipt.
    pub fn set_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    /// Add an out-route to `to` after construction (idempotent) — the
    /// lazy-edge hook behind [`bus_with_handles`].
    pub fn add_sender(&mut self, to: usize, tx: Sender<Msg>) {
        assert!(to < self.n && to != self.rank, "edge {}->{to} invalid for n={}", self.rank, self.n);
        if let Err(pos) = self.senders.binary_search_by_key(&to, |(j, _)| *j) {
            self.senders.insert(pos, (to, tx));
        }
    }

    /// Number of out-routes currently held.
    pub fn degree(&self) -> usize {
        self.senders.len()
    }

    pub fn bytes_sent(&self) -> u64 {
        self.scalars_sent * 4
    }
}

impl Wire for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }
    fn degree(&self) -> usize {
        Endpoint::degree(self)
    }
    fn traffic(&self) -> (u64, u64) {
        (self.scalars_sent, self.msgs_sent)
    }
    fn send_billed(&mut self, to: usize, payload: Vec<f32>, wire_scalars: u64) -> Result<()> {
        Endpoint::send_billed(self, to, payload, wire_scalars)
    }
    fn recv_from(&mut self, from: usize) -> Result<Vec<f32>> {
        Endpoint::recv_from(self, from)
    }
    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        Endpoint::set_recv_deadline(self, deadline)
    }
    fn reset_epoch(&mut self, epoch: u32) {
        Endpoint::reset_epoch(self, epoch)
    }
    fn set_epoch(&mut self, epoch: u32) {
        Endpoint::set_epoch(self, epoch)
    }
    fn stale_drops(&self) -> u64 {
        self.stale_drops
    }
}

/// One gossip round: node `rank` broadcasts `x` to its out-neighbors and
/// returns the weighted mix of what it receives.
///
/// `weight_row` is the node's row of W: `(j, w_ij)` over in-neighbors
/// (self included). For the symmetric/static topologies out-neighbors ==
/// in-neighbors; for the directed one-peer graph they differ — pass
/// [`crate::topology::Topology::out_neighbors`] so both cases are handled
/// uniformly.
pub fn gossip_exchange(
    ep: &mut Endpoint,
    x: &[f32],
    weight_row: &[(usize, f64)],
    out_neighbors: &[usize],
) -> Result<Vec<f32>> {
    for &j in out_neighbors {
        if j != ep.rank {
            ep.send(j, x.to_vec())?;
        }
    }
    let mut acc = vec![0.0f32; x.len()];
    for &(j, w) in weight_row {
        let w = w as f32;
        if j == ep.rank {
            for (a, b) in acc.iter_mut().zip(x) {
                *a += w * b;
            }
        } else {
            let recv = ep.recv_from(j)?;
            anyhow::ensure!(recv.len() == x.len(), "length mismatch from {j}");
            for (a, b) in acc.iter_mut().zip(&recv) {
                *a += w * b;
            }
        }
    }
    Ok(acc)
}

/// Chunk boundaries of the ring all-reduce: chunk c covers
/// `[c*d/n, (c+1)*d/n)`. Shared with the byte-accounting tests and the
/// [`crate::comm::BusBackend`]'s chunked global average so every layer
/// agrees on the same chunk math.
pub fn ring_chunk_bounds(n: usize, d: usize) -> Vec<usize> {
    (0..=n).map(|c| c * d / n).collect()
}

/// Exact per-node wire scalars of [`ring_all_reduce`]: rank r sends n-1 of
/// the n chunks once per phase — reduce-scatter skips `chunk((r+1) % n)`
/// and all-gather skips `chunk((r+2) % n)` — so the per-rank total is
/// `2d - len(chunk(r+1)) - len(chunk(r+2))`.
pub fn ring_all_reduce_scalars(n: usize, d: usize, rank: usize) -> u64 {
    if n == 1 {
        return 0;
    }
    let bounds = ring_chunk_bounds(n, d);
    let len = |c: usize| (bounds[c % n + 1] - bounds[c % n]) as u64;
    let mut total = 0u64;
    for s in 0..n - 1 {
        total += len((rank + n - s) % n); // reduce-scatter step s
        total += len((rank + 1 + n - s) % n); // all-gather step s
    }
    total
}

/// Bandwidth-optimal ring all-reduce: after the call every node holds the
/// element-wise **average** of all inputs.
///
/// Classic two-phase schedule over the ring `rank -> rank+1`:
/// reduce-scatter (n-1 steps, each sending one d/n chunk) then all-gather
/// (n-1 steps). Total traffic per node: 2 d (n-1)/n scalars — the 2·theta·d
/// of the paper's cost model. Requires the `rank -> rank+1` edge to exist
/// on the bus (a [`bus_for`] ring-successor edge set suffices).
pub fn ring_all_reduce(ep: &mut Endpoint, x: &mut [f32]) -> Result<()> {
    let n = ep.n;
    if n == 1 {
        return Ok(());
    }
    let d = x.len();
    let next = (ep.rank + 1) % n;
    let prev = (ep.rank + n - 1) % n;
    let bounds = ring_chunk_bounds(n, d);
    let chunk = |c: usize| bounds[c % n]..bounds[c % n + 1];

    // Reduce-scatter: at step s, send chunk (rank - s), reduce into
    // chunk (rank - s - 1).
    for s in 0..n - 1 {
        let send_c = (ep.rank + n - s) % n;
        let recv_c = (ep.rank + n - s - 1) % n;
        ep.send(next, x[chunk(send_c)].to_vec())?;
        let data = ep.recv_from(prev)?;
        for (a, b) in x[chunk(recv_c)].iter_mut().zip(&data) {
            *a += b;
        }
    }
    // All-gather: at step s, send chunk (rank + 1 - s) (now fully reduced).
    for s in 0..n - 1 {
        let send_c = (ep.rank + 1 + n - s) % n;
        let recv_c = (ep.rank + n - s) % n;
        ep.send(next, x[chunk(send_c)].to_vec())?;
        let data = ep.recv_from(prev)?;
        x[chunk(recv_c)].copy_from_slice(&data);
    }
    // Average.
    let inv = 1.0 / n as f32;
    for v in x.iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// Run `f` on every endpoint concurrently (one thread per node) and return
/// the per-node results in rank order. This is how the collectives are
/// exercised — each node is an independent thread exchanging messages, the
/// same concurrency structure as a real deployment.
pub fn run_nodes<T, F>(endpoints: Vec<Endpoint>, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(Endpoint) -> Result<T> + Send + Sync + 'static,
{
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::new();
    for ep in endpoints {
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(ep)));
    }
    handles
        .into_iter()
        .map(|h| h.join().map_err(|_| anyhow!("node thread panicked"))?)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn send_recv_basic() {
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, vec![1.0, 2.0]).unwrap();
        assert_eq!(b.recv_from(0).unwrap(), vec![1.0, 2.0]);
        assert_eq!(a.bytes_sent(), 8);
    }

    #[test]
    fn send_billed_overrides_wire_size() {
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        // Dense payload of 4 scalars billed as 1 (e.g. aggressive top-k).
        a.send_billed(1, vec![0.0, 0.0, 3.0, 0.0], 1).unwrap();
        assert_eq!(a.scalars_sent, 1);
        assert_eq!(a.msgs_sent, 1);
        assert_eq!(b.recv_from(0).unwrap().len(), 4, "dense payload intact");
    }

    #[test]
    fn recv_parks_out_of_order() {
        let mut eps = bus(3);
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(2, vec![1.0]).unwrap();
        b.send(2, vec![2.0]).unwrap();
        // Ask for b's first even though a's arrived first.
        assert_eq!(c.recv_from(1).unwrap(), vec![2.0]);
        assert_eq!(c.recv_from(0).unwrap(), vec![1.0]);
    }

    #[test]
    fn sparse_bus_rejects_missing_edge() {
        // Ring edges only: 0 -> {1, 2} is not an edge in a 4-ring.
        let edges: Vec<Vec<usize>> =
            (0..4).map(|i: usize| vec![(i + 1) % 4, (i + 3) % 4]).collect();
        let mut eps = bus_for(4, &edges);
        assert!(eps[0].send(1, vec![1.0]).is_ok());
        let err = eps[0].send(2, vec![1.0]).unwrap_err().to_string();
        assert!(err.contains("no channel"), "{err}");
        // A refused send must not count as traffic.
        assert_eq!(eps[0].msgs_sent, 1);
        assert_eq!(eps[0].scalars_sent, 1);
        // Self sends are never an edge.
        assert!(eps[0].send(0, vec![1.0]).is_err());
    }

    #[test]
    fn sparse_bus_sender_table_is_degree_sized() {
        let n = 64;
        let edges: Vec<Vec<usize>> =
            (0..n).map(|i: usize| vec![(i + 1) % n, (i + n - 1) % n]).collect();
        for ep in bus_for(n, &edges) {
            assert_eq!(ep.senders.len(), 2, "ring node holds exactly 2 senders");
        }
        // The fully-connected convenience still works.
        for ep in bus(5) {
            assert_eq!(ep.senders.len(), 4);
        }
    }

    #[test]
    fn ring_all_reduce_averages() {
        let n = 5;
        let d = 17; // deliberately not divisible by n
        let eps = bus(n);
        let results = run_nodes(eps, move |mut ep| {
            let mut x: Vec<f32> = (0..d).map(|j| (ep.rank * d + j) as f32).collect();
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .unwrap();
        // Expected average: for position j, mean over ranks of (r*d + j).
        let mean_rank = (0..n).sum::<usize>() as f32 / n as f32;
        for x in &results {
            for (j, v) in x.iter().enumerate() {
                let expect = mean_rank * d as f32 + j as f32;
                assert!((v - expect).abs() < 1e-3, "pos {j}: {v} vs {expect}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_non_power_of_two_and_tiny_sizes() {
        // Satellite sweep: n in {1, 2, 3, 5, 7, 8} x d in {1, 3, 17, 64},
        // including d < n (empty chunks on some ranks).
        for n in [1usize, 2, 3, 5, 7, 8] {
            for d in [1usize, 3, 17, 64] {
                let eps = bus(n);
                let results = run_nodes(eps, move |mut ep| {
                    let mut x: Vec<f32> =
                        (0..d).map(|j| ((ep.rank + 1) * (j + 1)) as f32).collect();
                    ring_all_reduce(&mut ep, &mut x)?;
                    Ok(x)
                })
                .unwrap();
                for (r, x) in results.iter().enumerate() {
                    for (j, v) in x.iter().enumerate() {
                        let expect = (0..n).map(|i| ((i + 1) * (j + 1)) as f32).sum::<f32>()
                            / n as f32;
                        assert!(
                            (v - expect).abs() < 1e-3,
                            "n={n} d={d} rank {r} pos {j}: {v} vs {expect}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ring_all_reduce_runs_on_successor_only_edges() {
        // The all-reduce needs exactly the rank -> rank+1 edge; a sparse
        // bus with only those edges must complete it.
        let n = 6;
        let d = 25;
        let edges: Vec<Vec<usize>> = (0..n).map(|i: usize| vec![(i + 1) % n]).collect();
        let eps = bus_for(n, &edges);
        let results = run_nodes(eps, move |mut ep| {
            let mut x = vec![(ep.rank + 1) as f32; d];
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .unwrap();
        let expect = (1..=n).sum::<usize>() as f32 / n as f32;
        for x in &results {
            assert!(x.iter().all(|v| (v - expect).abs() < 1e-4));
        }
    }

    #[test]
    fn ring_all_reduce_traffic_is_2d() {
        // Per-node traffic must be 2 d (n-1)/n scalars (the model's 2 theta d).
        let n = 4;
        let d = 400;
        let eps = bus(n);
        let sent = run_nodes(eps, move |mut ep| {
            let mut x = vec![1.0f32; d];
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(ep.scalars_sent)
        })
        .unwrap();
        for s in sent {
            assert_eq!(s, (2 * d * (n - 1) / n) as u64);
        }
    }

    #[test]
    fn ring_all_reduce_traffic_matches_chunk_math() {
        // Byte-accounting invariant: the measured per-edge scalars equal
        // the 2(n-1)-step chunk schedule exactly, and sum to
        // sum_ranks 2(d - len(chunk(rank+1))) = 2d(n-1) over all nodes,
        // even when d does not divide by n.
        for (n, d) in [(4usize, 400usize), (5, 17), (3, 7), (7, 64), (2, 1), (6, 5)] {
            let eps = bus(n);
            let sent = run_nodes(eps, move |mut ep| {
                let mut x = vec![1.0f32; d];
                ring_all_reduce(&mut ep, &mut x)?;
                Ok((ep.rank, ep.scalars_sent, ep.msgs_sent))
            })
            .unwrap();
            let mut total = 0u64;
            for (rank, scalars, msgs) in sent {
                let expect = ring_all_reduce_scalars(n, d, rank);
                assert_eq!(scalars, expect, "n={n} d={d} rank {rank}");
                assert_eq!(msgs, 2 * (n as u64 - 1), "n={n} d={d} rank {rank} msgs");
                total += scalars;
            }
            assert_eq!(total, 2 * (n as u64 - 1) * d as u64, "n={n} d={d} total");
        }
    }

    #[test]
    fn gossip_exchange_matches_matrix_product_every_kind() {
        // One gossip round over the bus == multiplying the stacked state by
        // W(round), on EVERY TopologyKind (the directed one-peer graph
        // exercises out-neighbors != in-neighbors on every round).
        let d = 3;
        for topo in [
            Topology::ring(6),
            Topology::grid(6),
            Topology::hypercube(8),
            Topology::star(5),
            Topology::full(5),
            Topology::static_expo(7),
            Topology::one_peer_expo(6),
        ] {
            let n = topo.n;
            for round in 0..topo.rounds() {
                let w = topo.weight_matrix(round);
                let eps = bus(n);
                let topo2 = topo.clone();
                let results = run_nodes(eps, move |mut ep| {
                    let x: Vec<f32> = (0..d).map(|j| (ep.rank * 10 + j) as f32).collect();
                    let row = topo2.weight_row(ep.rank, round);
                    let outn = topo2.out_neighbors(ep.rank, round);
                    gossip_exchange(&mut ep, &x, &row, &outn)
                })
                .unwrap();
                for i in 0..n {
                    for j in 0..d {
                        let expect: f64 =
                            (0..n).map(|k| w[(i, k)] * (k * 10 + j) as f64).sum();
                        assert!(
                            (results[i][j] as f64 - expect).abs() < 1e-4,
                            "{:?} round {round} node {i} col {j}",
                            topo.kind
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gossip_exchange_works_on_topology_sized_sparse_bus() {
        // The satellite's point: endpoints built from the topology's
        // out-neighbors (no fully-connected table) carry a gossip round.
        let topo = Topology::ring(8);
        let d = 4;
        let edges: Vec<Vec<usize>> = (0..topo.n).map(|i| topo.out_neighbors(i, 0)).collect();
        let eps = bus_for(topo.n, &edges);
        let topo2 = topo.clone();
        let results = run_nodes(eps, move |mut ep| {
            let x = vec![(ep.rank + 1) as f32; d];
            let row = topo2.weight_row(ep.rank, 0);
            let outn = topo2.out_neighbors(ep.rank, 0);
            gossip_exchange(&mut ep, &x, &row, &outn)
        })
        .unwrap();
        let w = topo.weight_matrix(0);
        for i in 0..topo.n {
            let expect: f64 = (0..topo.n).map(|k| w[(i, k)] * (k + 1) as f64).sum();
            assert!((results[i][0] as f64 - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn gossip_preserves_global_mean() {
        // Doubly-stochastic W preserves the average of the ensemble.
        let n = 8;
        let d = 5;
        let topo = Topology::grid(n);
        let eps = bus(n);
        let results = run_nodes(eps, move |mut ep| {
            let x: Vec<f32> = (0..d).map(|j| ((ep.rank + 1) * (j + 2)) as f32).collect();
            let row = topo.weight_row(ep.rank, 0);
            let outn = topo.out_neighbors(ep.rank, 0);
            gossip_exchange(&mut ep, &x, &row, &outn)
        })
        .unwrap();
        for j in 0..d {
            let before: f32 = (0..n).map(|i| ((i + 1) * (j + 2)) as f32).sum::<f32>() / n as f32;
            let after: f32 = results.iter().map(|x| x[j]).sum::<f32>() / n as f32;
            assert!((before - after).abs() < 1e-3);
        }
    }

    #[test]
    fn one_peer_gossip_counts_one_message_per_node() {
        // Directed one-peer round: every node transmits exactly once.
        let topo = Topology::one_peer_expo(8);
        let d = 16;
        for round in 0..topo.rounds() {
            let eps = bus(topo.n);
            let topo2 = topo.clone();
            let sent = run_nodes(eps, move |mut ep| {
                let x = vec![1.0f32; d];
                let row = topo2.weight_row(ep.rank, round);
                let outn = topo2.out_neighbors(ep.rank, round);
                gossip_exchange(&mut ep, &x, &row, &outn)?;
                Ok((ep.msgs_sent, ep.scalars_sent))
            })
            .unwrap();
            for (msgs, scalars) in sent {
                assert_eq!(msgs, 1, "round {round}");
                assert_eq!(scalars, d as u64, "round {round}");
            }
        }
    }

    #[test]
    fn node_failure_surfaces_as_error_not_hang() {
        // Failure injection: node 0 crashes before participating in the
        // all-reduce. Its ring neighbor must get a clean error (the sender
        // side hangs up), not a deadlock.
        let mut eps = bus(3);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a); // node 0 crashes
        let hb = std::thread::spawn(move || {
            let mut ep = b;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        let hc = std::thread::spawn(move || {
            let mut ep = c;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        // At least one of the survivors must observe the failure; neither
        // may hang (join() returning at all proves no deadlock).
        let rb = hb.join().unwrap();
        let rc = hc.join().unwrap();
        assert!(rb.is_err() || rc.is_err());
    }

    #[test]
    fn node_failure_on_sparse_bus_still_errors_cleanly() {
        // The crashed-peer => clean-Err property survives the sparse sender
        // table: with ring-successor edges only, dropping node 0 hangs up
        // node 1's inbound channel (and 2's once 1 exits).
        let edges: Vec<Vec<usize>> = (0..3).map(|i: usize| vec![(i + 1) % 3]).collect();
        let mut eps = bus_for(3, &edges);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a);
        let hb = std::thread::spawn(move || {
            let mut ep = b;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        let hc = std::thread::spawn(move || {
            let mut ep = c;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        let rb = hb.join().unwrap();
        let rc = hc.join().unwrap();
        assert!(rb.is_err() || rc.is_err());
    }

    #[test]
    fn message_to_dead_node_errors() {
        let mut eps = bus(2);
        let b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        drop(b);
        assert!(a.send(1, vec![1.0]).is_err());
        assert_eq!((a.msgs_sent, a.scalars_sent), (0, 0), "undelivered sends are not traffic");
    }

    #[test]
    fn recv_deadline_surfaces_stalled_peer_not_hang() {
        // ISSUE 7 satellite: node 0 is alive (channel open) but wedged —
        // pre-deadline, node 1's recv_from(0) parked forever. Watchdogged:
        // the receive must come back as a typed RecvTimeout naming node 0.
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let _a = eps.pop().unwrap(); // wedged: never sends, never drops
        b.set_recv_deadline(Some(Duration::from_millis(50)));
        let (done_tx, done_rx) = channel();
        std::thread::spawn(move || {
            let r = b.recv_from(0);
            done_tx.send(r).ok();
        });
        let r = done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("watchdog: deadline-armed recv_from hung on a wedged peer");
        let err = r.unwrap_err();
        let timeout = err.downcast_ref::<RecvTimeout>().expect("typed RecvTimeout");
        assert_eq!((timeout.waiter, timeout.from), (1, 0));
        assert_eq!(stalled_peer(&format!("{err:#}")), Some(0));
    }

    #[test]
    fn disarmed_deadline_keeps_blocking_semantics() {
        // Default endpoints still use the blocking receive: a crashed
        // (dropped) peer is a clean "bus closed" error, not a RecvTimeout.
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a);
        let err = b.recv_from(0).unwrap_err();
        assert!(err.downcast_ref::<RecvTimeout>().is_none());
        assert!(format!("{err}").contains("bus closed"), "{err}");
    }

    #[test]
    fn stalled_peer_parses_rendered_and_wrapped_errors() {
        let e = RecvTimeout { waiter: 3, from: 17, waited: Duration::from_millis(250) };
        assert_eq!(stalled_peer(&e.to_string()), Some(17));
        // The worker pool flattens job errors into "pool job i failed: ..."
        // strings; attribution must survive that wrapping.
        let wrapped = format!("pool job 3 failed: gossip recv phase: {e}");
        assert_eq!(stalled_peer(&wrapped), Some(17));
        assert_eq!(stalled_peer("bus closed waiting for 2"), None);
        assert_eq!(stalled_peer("node 1 hung up"), None);
    }

    #[test]
    fn stale_epoch_frames_are_discarded() {
        // A round retried after a drop must not mix the aborted attempt's
        // half-delivered frames: bump the receiver's epoch, then deliver a
        // stale frame followed by a current one.
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        b.reset_epoch(1);
        a.send(1, vec![1.0]).unwrap(); // epoch 0: the aborted round's frame
        a.reset_epoch(1);
        a.send(1, vec![2.0]).unwrap(); // epoch 1: the retry's frame
        assert_eq!(b.recv_from(0).unwrap(), vec![2.0], "stale frame skipped");
        assert_eq!(b.stale_drops, 1, "the discard is counted");
        // Nothing else queued: with a deadline armed the next recv times out
        // instead of replaying the stale payload.
        b.set_recv_deadline(Some(Duration::from_millis(20)));
        assert!(b.recv_from(0).unwrap_err().downcast_ref::<RecvTimeout>().is_some());
        assert_eq!(b.stale_drops, 1, "a timeout drops nothing");
    }

    #[test]
    fn set_epoch_retags_without_clearing_queued_frames() {
        // The overlapped-gossip stamp: a peer's send job may deliver a
        // round-t frame before our own endpoint is re-tagged to t. A
        // clearing reset would destroy it; set_epoch must not.
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.set_epoch(5);
        a.send(1, vec![42.0]).unwrap(); // round-5 frame, already in b's inbox
        b.set_epoch(5); // late re-tag: frame must survive
        assert_eq!(b.recv_from(0).unwrap(), vec![42.0]);
        assert_eq!(b.stale_drops, 0);
        // ...while a genuinely stale frame is still filtered and counted.
        a.set_epoch(4);
        a.send(1, vec![9.0]).unwrap();
        a.set_epoch(6);
        a.send(1, vec![10.0]).unwrap();
        b.set_epoch(6);
        assert_eq!(b.recv_from(0).unwrap(), vec![10.0]);
        assert_eq!(b.stale_drops, 1);
    }

    #[test]
    fn reset_epoch_clears_parked_frames() {
        let mut eps = bus(3);
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(2, vec![1.0]).unwrap();
        b.send(2, vec![2.0]).unwrap();
        // Park node 0's frame by asking for node 1's first.
        assert_eq!(c.recv_from(1).unwrap(), vec![2.0]);
        c.reset_epoch(1);
        a.reset_epoch(1);
        a.send(2, vec![3.0]).unwrap();
        assert_eq!(c.recv_from(0).unwrap(), vec![3.0], "parked epoch-0 frame dropped");
    }

    #[test]
    fn add_sender_wires_lazy_edges() {
        // A pure-gossip ring bus holds 2 senders per node; wiring the
        // chunk-exchange edges later brings it to n-1 — the lazy
        // construction contract the bus backend relies on.
        let n = 6;
        let edges: Vec<Vec<usize>> =
            (0..n).map(|i: usize| vec![(i + 1) % n, (i + n - 1) % n]).collect();
        let (mut eps, txs) = bus_with_handles(n, &edges);
        assert!(eps.iter().all(|ep| ep.degree() == 2));
        assert!(eps[0].send(3, vec![1.0]).is_err(), "no chord edge yet");
        for ep in eps.iter_mut() {
            for (j, tx) in txs.iter().enumerate() {
                if j != ep.rank {
                    ep.add_sender(j, tx.clone());
                    ep.add_sender(j, tx.clone()); // idempotent
                }
            }
        }
        assert!(eps.iter().all(|ep| ep.degree() == n - 1));
        let mut d = eps.remove(3);
        let mut a = eps.remove(0);
        a.send(3, vec![7.0]).unwrap();
        drop(txs); // handles gone: hangup semantics back to normal
        assert_eq!(d.recv_from(0).unwrap(), vec![7.0]);
    }

    #[test]
    fn all_reduce_single_node_noop() {
        let mut eps = bus(1);
        let mut x = vec![3.0f32, 4.0];
        ring_all_reduce(&mut eps[0], &mut x).unwrap();
        assert_eq!(x, vec![3.0, 4.0]);
        assert_eq!(eps[0].scalars_sent, 0);
        assert_eq!(ring_all_reduce_scalars(1, 2, 0), 0);
    }
}
