//! In-process collective-communication substrate.
//!
//! The paper's cluster (NCCL over 25 Gbps TCP) is replaced by a real
//! message-passing layer over `std::sync::mpsc` channels: each node owns an
//! [`Endpoint`] and communicates only by `send`/`recv`, exactly like a
//! socket-based worker would. On top of the bus we implement the two
//! primitives Algorithm 1 needs:
//!
//! * [`gossip_exchange`] — every node sends its vector to its out-neighbors
//!   and mixes what it receives with its weight row (the gossip branch);
//! * [`ring_all_reduce`] — bandwidth-optimal ring all-reduce
//!   (reduce-scatter + all-gather, 2(n-1) chunked steps), the paper's
//!   global-averaging primitive (§3, "All-Reduce v.s. multiple Gossips").
//!
//! Every endpoint counts bytes and messages so the Table 17 bench can report
//! measured traffic next to the alpha-beta model's predictions.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

/// A tagged message: (source, payload).
type Msg = (usize, Vec<f32>);

/// Per-node communication endpoint on the in-proc bus.
pub struct Endpoint {
    pub rank: usize,
    pub n: usize,
    /// `senders[j]` reaches node j; the self slot is `None` so that a
    /// node's own channel closes once every *other* node drops — this is
    /// what turns a crashed peer into a clean error instead of a deadlock
    /// (see `node_failure_surfaces_as_error_not_hang`).
    senders: Vec<Option<Sender<Msg>>>,
    receiver: Receiver<Msg>,
    /// Out-of-order arrivals parked until requested.
    parked: Vec<Msg>,
    /// Traffic accounting (payload f32 count and message count).
    pub scalars_sent: u64,
    pub msgs_sent: u64,
}

/// Build a fully-connected bus of `n` endpoints.
pub fn bus(n: usize) -> Vec<Endpoint> {
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| Endpoint {
            rank,
            n,
            senders: senders
                .iter()
                .enumerate()
                .map(|(j, tx)| (j != rank).then(|| tx.clone()))
                .collect(),
            receiver,
            parked: Vec::new(),
            scalars_sent: 0,
            msgs_sent: 0,
        })
        .collect()
}

impl Endpoint {
    /// Send `payload` to node `to`.
    pub fn send(&mut self, to: usize, payload: Vec<f32>) -> Result<()> {
        self.scalars_sent += payload.len() as u64;
        self.msgs_sent += 1;
        self.senders[to]
            .as_ref()
            .ok_or_else(|| anyhow!("node {} cannot send to itself", self.rank))?
            .send((self.rank, payload))
            .map_err(|_| anyhow!("node {to} hung up"))
    }

    /// Receive the next message from node `from` (parking others).
    pub fn recv_from(&mut self, from: usize) -> Result<Vec<f32>> {
        if let Some(pos) = self.parked.iter().position(|(src, _)| *src == from) {
            return Ok(self.parked.remove(pos).1);
        }
        loop {
            let (src, payload) =
                self.receiver.recv().map_err(|_| anyhow!("bus closed waiting for {from}"))?;
            if src == from {
                return Ok(payload);
            }
            self.parked.push((src, payload));
        }
    }

    pub fn bytes_sent(&self) -> u64 {
        self.scalars_sent * 4
    }
}

/// One gossip round: node `rank` broadcasts `x` to its out-neighbors and
/// returns the weighted mix of what it receives.
///
/// `weight_row` is the node's row of W: `(j, w_ij)` over in-neighbors
/// (self included). For the symmetric/static topologies out-neighbors ==
/// in-neighbors; for the directed one-peer graph the out-peer is the node
/// that lists `rank` among its in-neighbors — callers pass `out_neighbors`
/// explicitly so both cases are handled uniformly.
pub fn gossip_exchange(
    ep: &mut Endpoint,
    x: &[f32],
    weight_row: &[(usize, f64)],
    out_neighbors: &[usize],
) -> Result<Vec<f32>> {
    for &j in out_neighbors {
        if j != ep.rank {
            ep.send(j, x.to_vec())?;
        }
    }
    let mut acc = vec![0.0f32; x.len()];
    for &(j, w) in weight_row {
        let w = w as f32;
        if j == ep.rank {
            for (a, b) in acc.iter_mut().zip(x) {
                *a += w * b;
            }
        } else {
            let recv = ep.recv_from(j)?;
            anyhow::ensure!(recv.len() == x.len(), "length mismatch from {j}");
            for (a, b) in acc.iter_mut().zip(&recv) {
                *a += w * b;
            }
        }
    }
    Ok(acc)
}

/// Bandwidth-optimal ring all-reduce: after the call every node holds the
/// element-wise **average** of all inputs.
///
/// Classic two-phase schedule over the ring `rank -> rank+1`:
/// reduce-scatter (n-1 steps, each sending one d/n chunk) then all-gather
/// (n-1 steps). Total traffic per node: 2 d (n-1)/n scalars — the 2·theta·d
/// of the paper's cost model.
pub fn ring_all_reduce(ep: &mut Endpoint, x: &mut [f32]) -> Result<()> {
    let n = ep.n;
    if n == 1 {
        return Ok(());
    }
    let d = x.len();
    let next = (ep.rank + 1) % n;
    let prev = (ep.rank + n - 1) % n;
    // Chunk boundaries: chunk c covers [bound[c], bound[c+1]).
    let bounds: Vec<usize> = (0..=n).map(|c| c * d / n).collect();
    let chunk = |c: usize| bounds[c % n]..bounds[c % n + 1];

    // Reduce-scatter: at step s, send chunk (rank - s), reduce into
    // chunk (rank - s - 1).
    for s in 0..n - 1 {
        let send_c = (ep.rank + n - s) % n;
        let recv_c = (ep.rank + n - s - 1) % n;
        ep.send(next, x[chunk(send_c)].to_vec())?;
        let data = ep.recv_from(prev)?;
        for (a, b) in x[chunk(recv_c)].iter_mut().zip(&data) {
            *a += b;
        }
    }
    // All-gather: at step s, send chunk (rank + 1 - s) (now fully reduced).
    for s in 0..n - 1 {
        let send_c = (ep.rank + 1 + n - s) % n;
        let recv_c = (ep.rank + n - s) % n;
        ep.send(next, x[chunk(send_c)].to_vec())?;
        let data = ep.recv_from(prev)?;
        x[chunk(recv_c)].copy_from_slice(&data);
    }
    // Average.
    let inv = 1.0 / n as f32;
    for v in x.iter_mut() {
        *v *= inv;
    }
    Ok(())
}

/// Run `f` on every endpoint concurrently (one thread per node) and return
/// the per-node results in rank order. This is how the collectives are
/// exercised — each node is an independent thread exchanging messages, the
/// same concurrency structure as a real deployment.
pub fn run_nodes<T, F>(endpoints: Vec<Endpoint>, f: F) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(Endpoint) -> Result<T> + Send + Sync + 'static,
{
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::new();
    for ep in endpoints {
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(ep)));
    }
    handles
        .into_iter()
        .map(|h| h.join().map_err(|_| anyhow!("node thread panicked"))?)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn send_recv_basic() {
        let mut eps = bus(2);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, vec![1.0, 2.0]).unwrap();
        assert_eq!(b.recv_from(0).unwrap(), vec![1.0, 2.0]);
        assert_eq!(a.bytes_sent(), 8);
    }

    #[test]
    fn recv_parks_out_of_order() {
        let mut eps = bus(3);
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(2, vec![1.0]).unwrap();
        b.send(2, vec![2.0]).unwrap();
        // Ask for b's first even though a's arrived first.
        assert_eq!(c.recv_from(1).unwrap(), vec![2.0]);
        assert_eq!(c.recv_from(0).unwrap(), vec![1.0]);
    }

    #[test]
    fn ring_all_reduce_averages() {
        let n = 5;
        let d = 17; // deliberately not divisible by n
        let eps = bus(n);
        let results = run_nodes(eps, move |mut ep| {
            let mut x: Vec<f32> = (0..d).map(|j| (ep.rank * d + j) as f32).collect();
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(x)
        })
        .unwrap();
        // Expected average: for position j, mean over ranks of (r*d + j).
        let mean_rank = (0..n).sum::<usize>() as f32 / n as f32;
        for x in &results {
            for (j, v) in x.iter().enumerate() {
                let expect = mean_rank * d as f32 + j as f32;
                assert!((v - expect).abs() < 1e-3, "pos {j}: {v} vs {expect}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_traffic_is_2d() {
        // Per-node traffic must be 2 d (n-1)/n scalars (the model's 2 theta d).
        let n = 4;
        let d = 400;
        let eps = bus(n);
        let sent = run_nodes(eps, move |mut ep| {
            let mut x = vec![1.0f32; d];
            ring_all_reduce(&mut ep, &mut x)?;
            Ok(ep.scalars_sent)
        })
        .unwrap();
        for s in sent {
            assert_eq!(s, (2 * d * (n - 1) / n) as u64);
        }
    }

    #[test]
    fn gossip_exchange_matches_matrix_product() {
        // One gossip round over a ring == multiplying the stacked state by W.
        let n = 6;
        let d = 3;
        let topo = Topology::ring(n);
        let w = topo.weight_matrix(0);
        let eps = bus(n);
        let topo2 = topo.clone();
        let results = run_nodes(eps, move |mut ep| {
            let x: Vec<f32> = (0..d).map(|j| (ep.rank * 10 + j) as f32).collect();
            let row = topo2.weight_row(ep.rank, 0);
            let outn: Vec<usize> =
                topo2.in_neighbors(ep.rank, 0).into_iter().filter(|&j| j != ep.rank).collect();
            gossip_exchange(&mut ep, &x, &row, &outn)
        })
        .unwrap();
        for i in 0..n {
            for j in 0..d {
                let expect: f64 = (0..n).map(|k| w[(i, k)] * (k * 10 + j) as f64).sum();
                assert!((results[i][j] as f64 - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn gossip_preserves_global_mean() {
        // Doubly-stochastic W preserves the average of the ensemble.
        let n = 8;
        let d = 5;
        let topo = Topology::grid(n);
        let eps = bus(n);
        let results = run_nodes(eps, move |mut ep| {
            let x: Vec<f32> = (0..d).map(|j| ((ep.rank + 1) * (j + 2)) as f32).collect();
            let row = topo.weight_row(ep.rank, 0);
            let outn: Vec<usize> =
                topo.in_neighbors(ep.rank, 0).into_iter().filter(|&j| j != ep.rank).collect();
            gossip_exchange(&mut ep, &x, &row, &outn)
        })
        .unwrap();
        for j in 0..d {
            let before: f32 = (0..n).map(|i| ((i + 1) * (j + 2)) as f32).sum::<f32>() / n as f32;
            let after: f32 = results.iter().map(|x| x[j]).sum::<f32>() / n as f32;
            assert!((before - after).abs() < 1e-3);
        }
    }

    #[test]
    fn node_failure_surfaces_as_error_not_hang() {
        // Failure injection: node 0 crashes before participating in the
        // all-reduce. Its ring neighbor must get a clean error (the sender
        // side hangs up), not a deadlock.
        let mut eps = bus(3);
        let c = eps.pop().unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        drop(a); // node 0 crashes
        let hb = std::thread::spawn(move || {
            let mut ep = b;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        let hc = std::thread::spawn(move || {
            let mut ep = c;
            let mut x = vec![1.0f32; 9];
            ring_all_reduce(&mut ep, &mut x)
        });
        // At least one of the survivors must observe the failure; neither
        // may hang (join() returning at all proves no deadlock).
        let rb = hb.join().unwrap();
        let rc = hc.join().unwrap();
        assert!(rb.is_err() || rc.is_err());
    }

    #[test]
    fn message_to_dead_node_errors() {
        let mut eps = bus(2);
        let b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        drop(b);
        assert!(a.send(1, vec![1.0]).is_err());
    }

    #[test]
    fn all_reduce_single_node_noop() {
        let mut eps = bus(1);
        let mut x = vec![3.0f32, 4.0];
        ring_all_reduce(&mut eps[0], &mut x).unwrap();
        assert_eq!(x, vec![3.0, 4.0]);
    }
}
