//! Real-socket transport: length-prefixed frames over loopback TCP.
//!
//! The mpsc bus simulates message passing; this module does it over actual
//! `std::net::TcpStream`s so CommStats traffic is measured off a real wire.
//! The shapes mirror [`super::Endpoint`] deliberately:
//!
//! * **per-edge streams** — [`tcp_loopback`] dials one stream per directed
//!   edge in the out-edge lists it is given (the same lists `bus_for`
//!   takes), and [`TcpFabric::connect`] wires additional edges lazily, the
//!   hook the bus backend uses to defer its all-to-all chunk-exchange
//!   table until the first `global_average`;
//! * **frames** — every message is `u32 epoch | u32 count | count × f32`,
//!   little-endian, preceded on each stream by a one-shot `u32 src`
//!   handshake. A reader thread per inbound stream decodes frames into the
//!   node's inbox channel, so the receive path is the *same*
//!   [`super::recv_tagged`] the mpsc endpoint uses — parking,
//!   epoch-filtering, and stalled-peer deadlines included;
//! * **ports** — bind `host:0` and every node gets an OS-assigned port
//!   (the verify.sh contract: no hardcoded ports, no flakes); a non-zero
//!   port P pins node r to P + r for debugging.
//!
//! Crash detection differs from the mpsc bus on purpose: a TCP peer that
//! dies does not atomically close its receivers' channels (other streams
//! keep the inbox open), so liveness comes from the receive deadline — on a
//! real network "slow" and "dead" are indistinguishable, which is exactly
//! why the round state machine exists.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use super::{recv_tagged, Msg, Wire};

/// Refuse frames claiming more than this many scalars (1 GiB of f32s) —
/// a corrupt length prefix must not become a giant allocation.
const MAX_FRAME_SCALARS: usize = 1 << 28;

/// Decode loop for one inbound stream: read frames, push tagged messages
/// into the node's inbox. Exits on EOF/error (peer gone) or when the inbox
/// closes (endpoint dropped).
fn reader_loop(mut stream: TcpStream, src: usize, tx: Sender<Msg>) {
    let mut head = [0u8; 8];
    loop {
        if stream.read_exact(&mut head).is_err() {
            return;
        }
        let epoch = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let count = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if count > MAX_FRAME_SCALARS {
            return; // corrupt frame: drop the stream, not the process
        }
        let mut bytes = vec![0u8; count * 4];
        if stream.read_exact(&mut bytes).is_err() {
            return;
        }
        let payload: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if tx.send((src, epoch, payload)).is_err() {
            return;
        }
    }
}

/// The accept side of the loopback fabric: per-node listeners feeding
/// per-stream reader threads. Kept alive only as long as new edges may
/// still be dialed ([`TcpFabric::connect`]); dropping it shuts the
/// acceptors down while established streams keep flowing.
pub struct TcpFabric {
    addrs: Vec<SocketAddr>,
    shutdown: Arc<AtomicBool>,
    acceptors: Vec<JoinHandle<()>>,
}

impl TcpFabric {
    /// Listening addresses in rank order (OS-assigned ports visible here).
    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Dial a new directed edge `ep.rank -> to` (idempotent: an existing
    /// route is kept). This is the lazy chunk-exchange hook.
    pub fn connect(&self, ep: &mut TcpEndpoint, to: usize) -> Result<()> {
        ensure!(to < self.addrs.len() && to != ep.rank, "edge {}->{to} invalid", ep.rank);
        if ep.has_route(to) {
            return Ok(());
        }
        let mut stream = TcpStream::connect(self.addrs[to])
            .with_context(|| format!("dial node {to} at {}", self.addrs[to]))?;
        stream.set_nodelay(true).ok();
        stream
            .write_all(&(ep.rank as u32).to_le_bytes())
            .with_context(|| format!("handshake to node {to}"))?;
        ep.add_route(to, stream);
        Ok(())
    }
}

impl Drop for TcpFabric {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake each acceptor with a throwaway dial so it observes the flag.
        for addr in &self.addrs {
            TcpStream::connect(addr).ok();
        }
        for h in self.acceptors.drain(..) {
            h.join().ok();
        }
    }
}

/// Per-node endpoint over real sockets: same API surface as the mpsc
/// [`super::Endpoint`], same parking/epoch/deadline receive path, framed
/// streams underneath.
pub struct TcpEndpoint {
    pub rank: usize,
    pub n: usize,
    /// Outgoing streams, sorted by target rank (per-edge, like senders).
    writers: Vec<(usize, TcpStream)>,
    receiver: Receiver<Msg>,
    parked: Vec<Msg>,
    epoch: u32,
    recv_deadline: Option<Duration>,
    pub scalars_sent: u64,
    pub msgs_sent: u64,
    /// Frames discarded on receipt for carrying a stale epoch tag.
    pub stale_drops: u64,
}

impl TcpEndpoint {
    /// Does this endpoint already hold a stream to `to`?
    pub fn has_route(&self, to: usize) -> bool {
        self.writers.binary_search_by_key(&to, |(j, _)| *j).is_ok()
    }

    fn add_route(&mut self, to: usize, stream: TcpStream) {
        if let Err(pos) = self.writers.binary_search_by_key(&to, |(j, _)| *j) {
            self.writers.insert(pos, (to, stream));
        }
    }

    /// Number of out-routes currently held.
    pub fn degree(&self) -> usize {
        self.writers.len()
    }

    pub fn send(&mut self, to: usize, payload: Vec<f32>) -> Result<()> {
        let wire = payload.len() as u64;
        self.send_billed(to, payload, wire)
    }

    /// Frame and ship `payload`, billing `wire_scalars` — identical
    /// accounting semantics to the mpsc endpoint: only a fully written
    /// frame counts as traffic.
    pub fn send_billed(&mut self, to: usize, payload: Vec<f32>, wire_scalars: u64) -> Result<()> {
        let idx = self
            .writers
            .binary_search_by_key(&to, |(j, _)| *j)
            .map_err(|_| anyhow!("node {} has no channel to node {to}", self.rank))?;
        let mut frame = Vec::with_capacity(8 + payload.len() * 4);
        frame.extend_from_slice(&self.epoch.to_le_bytes());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        for v in &payload {
            frame.extend_from_slice(&v.to_le_bytes());
        }
        self.writers[idx].1.write_all(&frame).map_err(|_| anyhow!("node {to} hung up"))?;
        self.scalars_sent += wire_scalars;
        self.msgs_sent += 1;
        Ok(())
    }

    /// Receive the next current-epoch frame from node `from` (parking
    /// others); a deadline turns a silent peer into a typed
    /// [`super::RecvTimeout`].
    pub fn recv_from(&mut self, from: usize) -> Result<Vec<f32>> {
        recv_tagged(
            self.rank,
            &self.receiver,
            &mut self.parked,
            self.epoch,
            self.recv_deadline,
            from,
            &mut self.stale_drops,
        )
    }

    pub fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.recv_deadline = deadline;
    }

    pub fn reset_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
        self.parked.clear();
        while self.receiver.try_recv().is_ok() {}
    }

    /// Re-tag without clearing (see [`Wire::set_epoch`]): queued and parked
    /// frames survive; mismatched tags are filtered (and counted) on receipt.
    pub fn set_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    pub fn bytes_sent(&self) -> u64 {
        self.scalars_sent * 4
    }
}

impl Wire for TcpEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }
    fn degree(&self) -> usize {
        TcpEndpoint::degree(self)
    }
    fn traffic(&self) -> (u64, u64) {
        (self.scalars_sent, self.msgs_sent)
    }
    fn send_billed(&mut self, to: usize, payload: Vec<f32>, wire_scalars: u64) -> Result<()> {
        TcpEndpoint::send_billed(self, to, payload, wire_scalars)
    }
    fn recv_from(&mut self, from: usize) -> Result<Vec<f32>> {
        TcpEndpoint::recv_from(self, from)
    }
    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        TcpEndpoint::set_recv_deadline(self, deadline)
    }
    fn reset_epoch(&mut self, epoch: u32) {
        TcpEndpoint::reset_epoch(self, epoch)
    }
    fn set_epoch(&mut self, epoch: u32) {
        TcpEndpoint::set_epoch(self, epoch)
    }
    fn stale_drops(&self) -> u64 {
        self.stale_drops
    }
}

/// Build `n` loopback TCP endpoints wired with exactly the directed edges
/// in `out_edges` (the [`super::bus_for`] contract over real sockets).
///
/// `bind` is `host:port`; port 0 lets the OS assign every node's port
/// (the default and the CI contract), a non-zero port P pins node r to
/// P + r. Returns the endpoints plus the [`TcpFabric`] that accepts future
/// lazy edges — drop the fabric to freeze the edge set.
pub fn tcp_loopback(
    n: usize,
    out_edges: &[Vec<usize>],
    bind: &str,
) -> Result<(Vec<TcpEndpoint>, TcpFabric)> {
    ensure!(out_edges.len() == n, "one edge list per node");
    let base: SocketAddr =
        bind.parse().with_context(|| format!("listen address `{bind}` (want host:port)"))?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut addrs = Vec::with_capacity(n);
    let mut listeners = Vec::with_capacity(n);
    for rank in 0..n {
        let mut addr = base;
        if base.port() != 0 {
            addr.set_port(
                base.port()
                    .checked_add(rank as u16)
                    .ok_or_else(|| anyhow!("port range overflow at node {rank}"))?,
            );
        }
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind node {rank} at {addr}"))?;
        addrs.push(listener.local_addr()?);
        listeners.push(listener);
    }

    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        txs.push(tx);
        rxs.push(rx);
    }

    let acceptors = listeners
        .into_iter()
        .zip(txs)
        .map(|(listener, tx)| {
            let shutdown = shutdown.clone();
            std::thread::spawn(move || loop {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        stream.set_nodelay(true).ok();
                        // Bound the handshake read so a junk dial cannot
                        // wedge the acceptor.
                        stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                        let mut hs = [0u8; 4];
                        if stream.read_exact(&mut hs).is_err() {
                            continue;
                        }
                        let src = u32::from_le_bytes(hs) as usize;
                        stream.set_read_timeout(None).ok();
                        let tx = tx.clone();
                        std::thread::spawn(move || reader_loop(stream, src, tx));
                    }
                    Err(_) => {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                }
            })
        })
        .collect();

    let fabric = TcpFabric { addrs, shutdown, acceptors };
    let mut endpoints: Vec<TcpEndpoint> = (0..n)
        .map(|rank| TcpEndpoint {
            rank,
            n,
            writers: Vec::new(),
            receiver: rxs.remove(0),
            parked: Vec::new(),
            epoch: 0,
            recv_deadline: None,
            scalars_sent: 0,
            msgs_sent: 0,
            stale_drops: 0,
        })
        .collect();
    for (rank, targets) in out_edges.iter().enumerate() {
        let mut targets: Vec<usize> = targets.iter().copied().filter(|&j| j != rank).collect();
        targets.sort_unstable();
        targets.dedup();
        for j in targets {
            ensure!(j < n, "edge {rank}->{j} out of range for n={n}");
            fabric.connect(&mut endpoints[rank], j)?;
        }
    }
    Ok((endpoints, fabric))
}

#[cfg(test)]
mod tests {
    use super::super::{stalled_peer, RecvTimeout};
    use super::*;

    fn full_edges(n: usize) -> Vec<Vec<usize>> {
        (0..n).map(|i| (0..n).filter(|&j| j != i).collect()).collect()
    }

    #[test]
    fn frames_roundtrip_over_real_sockets() {
        let (mut eps, _fabric) = tcp_loopback(2, &full_edges(2), "127.0.0.1:0").unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, vec![1.0, -2.5, 3.25]).unwrap();
        assert_eq!(b.recv_from(0).unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!((a.scalars_sent, a.msgs_sent, a.bytes_sent()), (3, 1, 12));
        // Billed wire size is decoupled from the dense payload, as on mpsc.
        b.send_billed(0, vec![0.0; 8], 2).unwrap();
        assert_eq!(a.recv_from(1).unwrap().len(), 8);
        assert_eq!(b.scalars_sent, 2);
    }

    #[test]
    fn os_assigns_distinct_ports() {
        let (eps, fabric) = tcp_loopback(3, &full_edges(3), "127.0.0.1:0").unwrap();
        let mut ports: Vec<u16> = fabric.addrs().iter().map(|a| a.port()).collect();
        assert!(ports.iter().all(|&p| p != 0));
        ports.sort_unstable();
        ports.dedup();
        assert_eq!(ports.len(), 3, "one distinct port per node");
        drop(eps);
    }

    #[test]
    fn out_of_order_arrivals_park_like_the_bus() {
        let (mut eps, _fabric) = tcp_loopback(3, &full_edges(3), "127.0.0.1:0").unwrap();
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(2, vec![1.0]).unwrap();
        b.send(2, vec![2.0]).unwrap();
        assert_eq!(c.recv_from(1).unwrap(), vec![2.0]);
        assert_eq!(c.recv_from(0).unwrap(), vec![1.0]);
    }

    #[test]
    fn missing_edge_is_a_clean_error() {
        // Ring edges only: 0 -> 2 is not an edge; same message as the bus.
        let edges: Vec<Vec<usize>> = (0..4).map(|i: usize| vec![(i + 1) % 4]).collect();
        let (mut eps, _fabric) = tcp_loopback(4, &edges, "127.0.0.1:0").unwrap();
        assert_eq!(eps[0].degree(), 1);
        let err = eps[0].send(2, vec![1.0]).unwrap_err().to_string();
        assert!(err.contains("no channel"), "{err}");
        assert_eq!((eps[0].msgs_sent, eps[0].scalars_sent), (0, 0));
    }

    #[test]
    fn lazy_connect_adds_routes_idempotently() {
        let edges: Vec<Vec<usize>> = (0..4).map(|i: usize| vec![(i + 1) % 4]).collect();
        let (mut eps, fabric) = tcp_loopback(4, &edges, "127.0.0.1:0").unwrap();
        fabric.connect(&mut eps[0], 2).unwrap();
        fabric.connect(&mut eps[0], 2).unwrap();
        assert_eq!(eps[0].degree(), 2);
        eps[0].send(2, vec![9.0]).unwrap();
        let mut c = eps.remove(2);
        assert_eq!(c.recv_from(0).unwrap(), vec![9.0]);
    }

    #[test]
    fn stalled_tcp_peer_times_out_with_attribution() {
        // Node 0 wedges (stream open, nothing sent): the deadline-armed
        // receive must name node 0, watchdogged against hangs.
        let (mut eps, _fabric) = tcp_loopback(2, &full_edges(2), "127.0.0.1:0").unwrap();
        let mut b = eps.pop().unwrap();
        let _a = eps.pop().unwrap();
        b.set_recv_deadline(Some(Duration::from_millis(50)));
        let (done_tx, done_rx) = channel();
        std::thread::spawn(move || {
            done_tx.send(b.recv_from(0)).ok();
        });
        let r = done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("watchdog: deadline-armed tcp recv hung on a wedged peer");
        let err = r.unwrap_err();
        assert_eq!(err.downcast_ref::<RecvTimeout>().map(|t| t.from), Some(0));
        assert_eq!(stalled_peer(&format!("{err:#}")), Some(0));
    }

    #[test]
    fn stale_epoch_frames_filtered_on_the_wire() {
        let (mut eps, _fabric) = tcp_loopback(2, &full_edges(2), "127.0.0.1:0").unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        b.reset_epoch(1);
        a.send(1, vec![1.0]).unwrap(); // epoch 0: aborted round's frame
        a.reset_epoch(1);
        a.send(1, vec![2.0]).unwrap(); // epoch 1: the retry
        // TCP preserves stream order, so the stale frame arrives first and
        // must be filtered, not parked.
        assert_eq!(b.recv_from(0).unwrap(), vec![2.0]);
        assert_eq!(b.stale_drops, 1, "the on-the-wire discard is counted");
    }
}
