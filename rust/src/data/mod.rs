//! Synthetic datasets + iid/non-iid sharding.
//!
//! Three generators, one per experiment family:
//!
//! * [`LogRegData`] — the paper's §5.1 recipe, verbatim: features
//!   h ~ N(0, 10 I_d); per-node ground truth x_i* with N(0,1) entries,
//!   normalized; labels y = +1 with prob sigmoid(h^T x*). iid scenario
//!   shares one x* across nodes, non-iid draws x_i* per node.
//! * [`ClusterData`] — Gaussian-cluster classification standing in for
//!   ImageNet (Tables 7/9/10/15/16): class centers ~ N(0, I) * sep,
//!   samples = center + N(0, I). non-iid sharding gives each node a
//!   label-skewed shard (sorted-by-label contiguous split, the standard
//!   federated pathological split).
//! * [`TokenCorpus`] — order-1 Markov chain text with ~`branching` likely
//!   successors per token: entropy floor ln(branching), so an LM that
//!   learns approaches that loss. Stands in for Wikipedia/Books (Table 11).

use crate::rng::Rng;

/// Per-node logistic-regression dataset (flattened row-major features).
#[derive(Clone, Debug)]
pub struct LogRegData {
    pub d: usize,
    /// xs[i]: node i's features, m x d row-major.
    pub xs: Vec<Vec<f32>>,
    /// ys[i]: node i's +-1 labels.
    pub ys: Vec<Vec<f32>>,
    pub samples_per_node: usize,
}

impl LogRegData {
    /// Generate the paper's §5.1 data for `n` nodes.
    pub fn generate(n: usize, d: usize, samples_per_node: usize, non_iid: bool, seed: u64) -> Self {
        let root = Rng::new(seed);
        let mut star_rng = root.split(u64::MAX);
        let shared_star = normalized_normal(&mut star_rng, d);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let mut rng = root.split(i as u64);
            let star = if non_iid { normalized_normal(&mut rng, d) } else { shared_star.clone() };
            let mut x = Vec::with_capacity(samples_per_node * d);
            let mut y = Vec::with_capacity(samples_per_node);
            for _ in 0..samples_per_node {
                let mut dot = 0.0f64;
                for _ in 0..d {
                    // N(0, 10 I): std = sqrt(10).
                    let h = rng.normal() * 10f64.sqrt();
                    x.push(h as f32);
                    // dot computed below over the row just pushed
                }
                let row = &x[x.len() - d..];
                for (hv, sv) in row.iter().zip(&star) {
                    dot += *hv as f64 * *sv as f64;
                }
                let p = 1.0 / (1.0 + (-dot).exp());
                y.push(rng.sign_label(p));
            }
            xs.push(x);
            ys.push(y);
        }
        LogRegData { d, xs, ys, samples_per_node }
    }

    /// Sample a minibatch (with replacement) for node `i` into caller
    /// buffers — zero allocation on the training path.
    pub fn sample_batch(
        &self,
        node: usize,
        batch: usize,
        rng: &mut Rng,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<f32>,
    ) {
        x_out.clear();
        y_out.clear();
        for _ in 0..batch {
            let s = rng.below(self.samples_per_node as u64) as usize;
            x_out.extend_from_slice(&self.xs[node][s * self.d..(s + 1) * self.d]);
            y_out.push(self.ys[node][s]);
        }
    }
}

fn normalized_normal(rng: &mut Rng, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-12) as f32;
    v.iter_mut().for_each(|x| *x /= norm);
    v
}

/// Gaussian-cluster classification dataset, globally generated then sharded.
#[derive(Clone, Debug)]
pub struct ClusterData {
    pub in_dim: usize,
    pub classes: usize,
    /// Per-node shards.
    pub xs: Vec<Vec<f32>>,
    pub ys: Vec<Vec<i32>>,
    pub samples_per_node: usize,
    /// Held-out eval set (shared).
    pub eval_x: Vec<f32>,
    pub eval_y: Vec<i32>,
}

impl ClusterData {
    pub fn generate(
        n: usize,
        in_dim: usize,
        classes: usize,
        samples_per_node: usize,
        eval_samples: usize,
        non_iid: bool,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1A55);
        // Deliberately hard-ish: overlapping clusters + 5% train-label
        // noise so the method suite differentiates instead of saturating
        // at 100% (the eval set stays clean).
        let sep = 0.8f32;
        let label_noise = 0.05;
        let centers: Vec<Vec<f32>> =
            (0..classes).map(|_| rng.normal_vec(in_dim, sep)).collect();
        let total = n * samples_per_node;
        let mut all_x = Vec::with_capacity(total * in_dim);
        let mut all_y = Vec::with_capacity(total);
        let mut order: Vec<usize> = (0..total).collect();
        for i in 0..total {
            let c = if non_iid {
                // label-sorted: node shards become class-skewed
                (i * classes) / total
            } else {
                rng.below(classes as u64) as usize
            };
            for j in 0..in_dim {
                all_x.push(centers[c][j] + rng.normal() as f32);
            }
            let noisy = if rng.f64() < label_noise {
                rng.below(classes as u64) as usize
            } else {
                c
            };
            all_y.push(noisy as i32);
        }
        if !non_iid {
            rng.shuffle(&mut order);
        }
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for node in 0..n {
            let mut x = Vec::with_capacity(samples_per_node * in_dim);
            let mut y = Vec::with_capacity(samples_per_node);
            for s in 0..samples_per_node {
                let idx = order[node * samples_per_node + s];
                x.extend_from_slice(&all_x[idx * in_dim..(idx + 1) * in_dim]);
                y.push(all_y[idx]);
            }
            xs.push(x);
            ys.push(y);
        }
        // Balanced eval set.
        let mut eval_x = Vec::with_capacity(eval_samples * in_dim);
        let mut eval_y = Vec::with_capacity(eval_samples);
        for i in 0..eval_samples {
            let c = i % classes;
            for j in 0..in_dim {
                eval_x.push(centers[c][j] + rng.normal() as f32);
            }
            eval_y.push(c as i32);
        }
        ClusterData { in_dim, classes, xs, ys, samples_per_node, eval_x, eval_y }
    }

    pub fn sample_batch(
        &self,
        node: usize,
        batch: usize,
        rng: &mut Rng,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<i32>,
    ) {
        x_out.clear();
        y_out.clear();
        for _ in 0..batch {
            let s = rng.below(self.samples_per_node as u64) as usize;
            x_out.extend_from_slice(&self.xs[node][s * self.in_dim..(s + 1) * self.in_dim]);
            y_out.push(self.ys[node][s]);
        }
    }

    /// Per-node label histogram — used to verify non-iid skew in tests.
    pub fn label_histogram(&self, node: usize) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &y in &self.ys[node] {
            h[y as usize] += 1;
        }
        h
    }
}

/// Order-1 Markov token stream over `vocab` tokens.
#[derive(Clone, Debug)]
pub struct TokenCorpus {
    pub vocab: usize,
    /// succ[t]: the `branching` likely successors of token t.
    succ: Vec<Vec<u32>>,
    pub branching: usize,
}

impl TokenCorpus {
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x70C0);
        let succ = (0..vocab)
            .map(|_| (0..branching).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        TokenCorpus { vocab, succ, branching }
    }

    /// Entropy floor of the chain (nats) — the best achievable LM loss.
    pub fn entropy_floor(&self) -> f64 {
        // 90% mass uniform over `branching` successors, 10% uniform noise.
        let p_succ = 0.9 / self.branching as f64;
        let p_noise = 0.1 / self.vocab as f64;
        // Approximate: successors are (p_succ + p_noise) each.
        let ps = p_succ + p_noise;
        -(self.branching as f64 * ps * ps.ln()
            + (self.vocab - self.branching) as f64 * p_noise * p_noise.ln())
    }

    /// Fill `out` with a (batch, seq_len+1) i32 token block for node `node`.
    pub fn sample_batch(
        &self,
        batch: usize,
        seq_plus_one: usize,
        rng: &mut Rng,
        out: &mut Vec<i32>,
    ) {
        out.clear();
        for _ in 0..batch {
            let mut t = rng.below(self.vocab as u64) as u32;
            out.push(t as i32);
            for _ in 1..seq_plus_one {
                t = if rng.f64() < 0.9 {
                    let s = &self.succ[t as usize];
                    s[rng.below(s.len() as u64) as usize]
                } else {
                    rng.below(self.vocab as u64) as u32
                };
                out.push(t as i32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_shapes_and_labels() {
        let data = LogRegData::generate(4, 10, 100, true, 1);
        assert_eq!(data.xs.len(), 4);
        assert_eq!(data.xs[0].len(), 1000);
        assert!(data.ys[0].iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn logreg_iid_vs_noniid_heterogeneity() {
        // Non-iid nodes have different optimal directions => label patterns
        // on the SAME features would differ. Proxy: per-node label means
        // diverge more in non-iid data.
        let iid = LogRegData::generate(8, 10, 2000, false, 3);
        let non = LogRegData::generate(8, 10, 2000, true, 3);
        let spread = |d: &LogRegData| {
            let means: Vec<f64> = d
                .ys
                .iter()
                .map(|y| y.iter().map(|&v| v as f64).sum::<f64>() / y.len() as f64)
                .collect();
            let m = means.iter().sum::<f64>() / means.len() as f64;
            means.iter().map(|x| (x - m).powi(2)).sum::<f64>()
        };
        // Weak assertion: both balanced-ish near 0 mean but distinct datasets.
        assert!(spread(&iid).is_finite() && spread(&non).is_finite());
        assert_ne!(iid.ys[0], non.ys[0]);
    }

    #[test]
    fn logreg_features_have_variance_ten() {
        let data = LogRegData::generate(1, 10, 5000, false, 7);
        let xs = &data.xs[0];
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((var - 10.0).abs() < 0.5, "var {var}");
    }

    #[test]
    fn logreg_batch_sampling() {
        let data = LogRegData::generate(2, 5, 50, false, 2);
        let mut rng = Rng::new(9);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        data.sample_batch(1, 8, &mut rng, &mut x, &mut y);
        assert_eq!(x.len(), 40);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn cluster_noniid_shards_are_skewed() {
        let data = ClusterData::generate(4, 8, 4, 400, 64, true, 5);
        // Each node sees ~1 dominant class in the pathological split
        // (label noise adds a small tail).
        let h0 = data.label_histogram(0);
        let dominant = *h0.iter().max().unwrap();
        assert!(dominant as f64 >= 0.9 * 400.0, "{h0:?}");
        // iid shards see all classes.
        let iid = ClusterData::generate(4, 8, 4, 400, 64, false, 5);
        let h = iid.label_histogram(0);
        assert!(h.iter().all(|&c| c > 0), "{h:?}");
    }

    #[test]
    fn cluster_eval_is_balanced() {
        let data = ClusterData::generate(2, 8, 4, 100, 64, false, 6);
        let mut h = vec![0; 4];
        for &y in &data.eval_y {
            h[y as usize] += 1;
        }
        assert!(h.iter().all(|&c| c == 16), "{h:?}");
    }

    #[test]
    fn corpus_tokens_in_range_and_learnable() {
        let c = TokenCorpus::new(256, 4, 11);
        let mut rng = Rng::new(1);
        let mut out = Vec::new();
        c.sample_batch(4, 33, &mut rng, &mut out);
        assert_eq!(out.len(), 4 * 33);
        assert!(out.iter().all(|&t| (0..256).contains(&t)));
        // Entropy floor well below uniform ln(256) = 5.55.
        assert!(c.entropy_floor() < 3.0, "{}", c.entropy_floor());
        assert!(c.entropy_floor() > 1.0);
    }

    #[test]
    fn corpus_transitions_are_biased() {
        // Successor pairs should repeat far more often than uniform chance.
        let c = TokenCorpus::new(64, 2, 13);
        let mut rng = Rng::new(2);
        let mut out = Vec::new();
        c.sample_batch(64, 65, &mut rng, &mut out);
        let mut seen = std::collections::HashMap::new();
        for row in out.chunks(65) {
            for w in row.windows(2) {
                *seen.entry((w[0], w[1])).or_insert(0usize) += 1;
            }
        }
        // 64*64 transitions observed over 4096 possible pairs; biased chains
        // concentrate: top pair count must beat the uniform expectation (1).
        let max = seen.values().max().copied().unwrap_or(0);
        assert!(max > 5, "max pair count {max}");
    }

    #[test]
    fn generation_is_deterministic() {
        let a = LogRegData::generate(3, 4, 10, true, 77);
        let b = LogRegData::generate(3, 4, 10, true, 77);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
    }
}
