//! Rust-side model descriptors: flat-parameter layouts mirrored from the
//! Python L2 definitions, used to initialize worker parameters without
//! touching Python at runtime.
//!
//! The layouts are reconstructed from the manifest's hyper-parameter meta
//! and cross-checked against its `flat_dim` (tests + a hard assert in the
//! constructors), so a drift between `python/compile/*.py` and this module
//! fails loudly instead of silently mis-initializing.

use crate::rng::Rng;

/// One tensor entry in a flat layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl Entry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Init style per tensor, mirroring python's initializers.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Init {
    Zero,
    One,
    /// N(0, scale^2)
    Normal(f64),
}

/// A flat-parameter layout.
#[derive(Clone, Debug)]
pub struct Layout {
    pub entries: Vec<Entry>,
    inits: Vec<Init>,
    pub dim: usize,
}

impl Layout {
    fn build(specs: Vec<(String, Vec<usize>, Init)>) -> Layout {
        let mut entries = Vec::with_capacity(specs.len());
        let mut inits = Vec::with_capacity(specs.len());
        let mut offset = 0;
        for (name, shape, init) in specs {
            let size: usize = shape.iter().product();
            entries.push(Entry { name, shape, offset });
            inits.push(init);
            offset += size;
        }
        Layout { entries, inits, dim: offset }
    }

    /// Initialize a flat parameter vector (identical across workers, per
    /// Algorithm 1's requirement that x_i^(0) be equal).
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; self.dim];
        for (e, init) in self.entries.iter().zip(&self.inits) {
            let slice = &mut flat[e.offset..e.offset + e.size()];
            match init {
                Init::Zero => {}
                Init::One => slice.fill(1.0),
                Init::Normal(scale) => {
                    for v in slice.iter_mut() {
                        *v = (rng.normal() * scale) as f32;
                    }
                }
            }
        }
        flat
    }

    pub fn entry(&self, name: &str) -> Option<&Entry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// Logistic regression: a single (d,) weight vector, zero-initialized
/// (paper §5.1 starts all runs from the same point).
pub fn logreg_layout(d: usize) -> Layout {
    Layout::build(vec![("w".into(), vec![d], Init::Zero)])
}

/// The 2-layer MLP classifier, mirroring `python/compile/model.MlpLayout`.
pub fn mlp_layout(in_dim: usize, hidden: usize, classes: usize) -> Layout {
    Layout::build(vec![
        ("w1".into(), vec![in_dim, hidden], Init::Normal(1.0 / (in_dim as f64).sqrt())),
        ("b1".into(), vec![hidden], Init::Zero),
        ("w2".into(), vec![hidden, classes], Init::Normal(1.0 / (hidden as f64).sqrt())),
        ("b2".into(), vec![classes], Init::Zero),
    ])
}

/// Transformer hyper-parameters (mirrors `transformer.TransformerConfig`).
#[derive(Clone, Copy, Debug)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

/// The decoder-only LM, mirroring `transformer.TransformerLayout`.
pub fn transformer_layout(cfg: &TransformerConfig) -> Layout {
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let dscale = 1.0 / (d as f64).sqrt();
    let depth = (2.0 * cfg.n_layers as f64).sqrt();
    let mut specs: Vec<(String, Vec<usize>, Init)> = vec![
        ("embed".into(), vec![cfg.vocab, d], Init::Normal(1.0 / (cfg.vocab as f64).sqrt())),
        ("pos".into(), vec![cfg.seq_len, d], Init::Normal(0.01)),
    ];
    for layer in 0..cfg.n_layers {
        let p = format!("l{layer}.");
        specs.push((p.clone() + "ln1_g", vec![d], Init::One));
        specs.push((p.clone() + "ln1_b", vec![d], Init::Zero));
        specs.push((p.clone() + "wq", vec![d, d], Init::Normal(dscale)));
        specs.push((p.clone() + "wk", vec![d, d], Init::Normal(dscale)));
        specs.push((p.clone() + "wv", vec![d, d], Init::Normal(dscale)));
        specs.push((p.clone() + "wo", vec![d, d], Init::Normal(dscale / depth)));
        specs.push((p.clone() + "ln2_g", vec![d], Init::One));
        specs.push((p.clone() + "ln2_b", vec![d], Init::Zero));
        specs.push((p.clone() + "w1", vec![d, ff], Init::Normal(dscale)));
        specs.push((p.clone() + "b1", vec![ff], Init::Zero));
        specs.push((p.clone() + "w2", vec![ff, d], Init::Normal((1.0 / (ff as f64).sqrt()) / depth)));
        specs.push((p + "b2", vec![d], Init::Zero));
    }
    specs.push(("lnf_g".into(), vec![d], Init::One));
    specs.push(("lnf_b".into(), vec![d], Init::Zero));
    // Untied output head (see python/compile/transformer.py for why).
    specs.push(("head".into(), vec![d, cfg.vocab], Init::Normal(dscale)));
    Layout::build(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_layout_dim() {
        assert_eq!(logreg_layout(10).dim, 10);
        let flat = logreg_layout(10).init(0);
        assert!(flat.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn mlp_layout_matches_python_formula() {
        // python: in*h + h + h*c + c
        let l = mlp_layout(32, 128, 10);
        assert_eq!(l.dim, 32 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(l.entry("w2").unwrap().offset, 32 * 128 + 128);
    }

    #[test]
    fn transformer_layout_matches_python_formula() {
        let cfg = TransformerConfig { vocab: 256, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 256, seq_len: 32 };
        let l = transformer_layout(&cfg);
        let d = 64;
        let per_layer = 2 * d + 4 * d * d + 2 * d + d * 256 + 256 + 256 * d + d;
        assert_eq!(l.dim, 256 * d + 32 * d + 2 * per_layer + 2 * d + d * 256);
    }

    #[test]
    fn init_statistics() {
        let l = mlp_layout(64, 64, 8);
        let flat = l.init(7);
        // gains/biases zero, weights ~ N(0, 1/64): check w1 std.
        let w1 = &flat[..64 * 64];
        let mean: f64 = w1.iter().map(|&x| x as f64).sum::<f64>() / w1.len() as f64;
        let var: f64 = w1.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / w1.len() as f64;
        assert!(mean.abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 64.0).abs() < 0.005, "{var}");
        let b1 = &flat[64 * 64..64 * 64 + 64];
        assert!(b1.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_deterministic() {
        let l = mlp_layout(8, 8, 2);
        assert_eq!(l.init(3), l.init(3));
        assert_ne!(l.init(3), l.init(4));
    }

    #[test]
    fn layouts_match_manifest_if_present() {
        let dir = crate::artifacts_dir();
        if let Ok(m) = crate::runtime::manifest::Manifest::load(&dir) {
            for a in &m.artifacts {
                let dim = match a.model.as_str() {
                    "logreg" => logreg_layout(a.flat_dim).dim,
                    "mlp" => mlp_layout(
                        a.meta_usize("in_dim").unwrap(),
                        a.meta_usize("hidden").unwrap(),
                        a.meta_usize("classes").unwrap(),
                    )
                    .dim,
                    "transformer" if a.kind == "grad" => transformer_layout(&TransformerConfig {
                        vocab: a.meta_usize("vocab").unwrap(),
                        d_model: a.meta_usize("d_model").unwrap(),
                        n_layers: a.meta_usize("n_layers").unwrap(),
                        n_heads: a.meta_usize("n_heads").unwrap(),
                        d_ff: a.meta_usize("d_ff").unwrap(),
                        seq_len: a.meta_usize("seq_len").unwrap(),
                    })
                    .dim,
                    _ => continue,
                };
                assert_eq!(dim, a.flat_dim, "layout drift for artifact {}", a.name);
            }
        }
    }
}
