//! Minimal randomized-property testing kit (proptest is unavailable
//! offline).
//!
//! No shrinking — on failure the kit reports the exact seed + case index so
//! the failing input is reproducible with `PROP_SEED=<seed>`. Case counts
//! default to 64 and can be adjusted with `PROPTEST_CASES` (the
//! conventional name, used by scripts/verify.sh) or the legacy
//! `PROP_CASES`.
//!
//! ```ignore
//! proptest::check("mix preserves mean", |rng| {
//!     let n = 2 + rng.below(16) as usize;
//!     /* build input, return Ok(()) or Err(description) */
//! });
//! ```

use crate::rng::Rng;

/// Per-case verdict: `Err(msg)` fails the property with context.
pub type CaseResult = Result<(), String>;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The number of cases each property runs: `PROPTEST_CASES`, falling back
/// to the legacy `PROP_CASES`, falling back to 64.
pub fn case_count() -> u64 {
    env_u64("PROPTEST_CASES", env_u64("PROP_CASES", 64))
}

/// Run `prop` over [`case_count`] random cases; panic with seed on failure.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, mut prop: F) {
    let seed = env_u64("PROP_SEED", 0xC0FFEE);
    let cases = case_count();
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.split(case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> CaseResult {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert a scalar predicate with a labelled message.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check("trivially true", |_| {
            count += 1;
            Ok(())
        });
        // Respect whatever the environment asked for (verify.sh pins its
        // own 16-case floor) rather than hard-coding the default.
        assert_eq!(count, case_count());
        assert!(count >= 1);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        check("always false", |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_tolerates_noise() {
        assert!(assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        check("capture", |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("capture again", |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
