//! `gossip-pga` — launcher CLI.
//!
//! Subcommands:
//!   train [--config exp.toml] [--set key=value ...] [--threads N]
//!         [--regime bsp|overlap|async] [--max-staleness S]
//!         [--overlap] [--stealing] [--pin] [--pipeline-depth K]
//!         [--backend shared|bus|tcp] [--trace out.json]
//!         [--listen host:port] [--round-timeout SECS]
//!         [--straggler idx:factor[,idx:factor...]]    run one experiment
//!   trace out.json                                    summarize a trace file
//!   topo  [--n N]                                     topology/beta report
//!   check                                             verify artifacts load
//!
//! (clap is unavailable offline; flags are parsed by the tiny parser below.)

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use gossip_pga::config::{ExperimentConfig, Toml};
use gossip_pga::coordinator::{self, TrainerOptions};
use gossip_pga::harness::Table;
use gossip_pga::runtime::Runtime;
use gossip_pga::topology::{spectral, Topology};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("topo") => cmd_topo(&args[1..]),
        Some("check") => cmd_check(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn print_help() {
    println!(
        "gossip-pga — Gossip SGD with Periodic Global Averaging (ICML 2021)\n\
         \n\
         USAGE:\n\
           gossip-pga train [--config exp.toml] [--set key=value ...] [--threads N]\n\
                            [--regime bsp|overlap|async] [--max-staleness S]\n\
                            [--overlap] [--stealing] [--pin] [--pipeline-depth K]\n\
                            [--backend shared|bus|tcp] [--trace out.json]\n\
                            [--listen host:port] [--round-timeout SECS]\n\
                            [--straggler idx:factor[,idx:factor...]]\n\
           gossip-pga trace out.json\n\
           gossip-pga sweep [--virtual-n N] [--surrogate] [--dim D] [--steps K]\n\
                            [--topology T] [--algo A] [--period H] [--max-staleness S]\n\
                            [--churn SCRIPT] [--churn-pairs P --churn-horizon SECS]\n\
                            [--churn-seed SEED] [--regions k:mult] [--seed SEED]\n\
                            [--cost-dim D] [--straggler idx:factor] [--log-points P]\n\
                            [--report out.json]\n\
           gossip-pga topo [--n N]\n\
           gossip-pga check\n\
         \n\
         sweep: the virtual population plane — n simulated nodes (clocks,\n\
           staleness, link occupancy, exact traffic billing) over pooled payload\n\
           storage; reaches n = 100000. --surrogate runs (mean, var) payloads\n\
           with zero dense allocation; --dim D runs a dense drift model. Churn\n\
           scripts: crash@t:n, rejoin@t:n, flaky@t:src>dst:factor,\n\
           restore@t:src>dst (comma-separated), or seeded pairs via\n\
           --churn-pairs/--churn-horizon. --regions k:mult slows cross-region\n\
           links by mult.\n\
         \n\
         trace: summarize a Chrome trace-event file written by train --trace\n\
           into a per-phase table (count, p50/p99/total wall, sim seconds, per\n\
           node) plus the final counter-track values. The file also loads\n\
           directly in Perfetto (ui.perfetto.dev) or chrome://tracing.\n\
         \n\
         Config keys (TOML paths, also usable with --set):\n\
           cluster.nodes, cluster.topology (ring|grid|star|full|expo|one-peer-expo)\n\
           algorithm.name (parallel|gossip|local|pga|aga|slowmo), algorithm.period\n\
           model.name (logreg|mlp|transformer), model.tag (tiny|e2e)\n\
           train.steps, train.lr, train.momentum, train.seed, data.non_iid\n\
           train.threads (worker-pool size; --threads N is shorthand)\n\
           train.regime (bsp|overlap|async; --regime is shorthand. async = the\n\
             event-driven AD-PSGD plane: per-node iteration counters, per-link\n\
             billing, bounded-stale mixing)\n\
           train.max_staleness (async regime: how many versions behind BSP-fresh\n\
             a mix input may be; 0 = strict, reproduces BSP bit-exactly)\n\
           train.overlap (double-buffered async gossip; --overlap is shorthand\n\
             for --regime overlap)\n\
           train.stealing (work-stealing pool chunking; --stealing is shorthand)\n\
           train.pin (pin pool threads to cores, best-effort; --pin is shorthand.\n\
             Needs train.threads <= available cores; bits identical either way)\n\
           train.pipeline_depth (max gossip rounds in flight on any backend's\n\
             async pipeline — shared, bus, and tcp all overlap; 1 = classic\n\
             double buffer, drained at every k·H/eval/checkpoint boundary;\n\
             --pipeline-depth is shorthand)\n\
           comm.backend (shared|bus|tcp; --backend is shorthand. tcp = the bus\n\
             core over real loopback sockets — framed streams, measured traffic)\n\
           comm.listen (tcp bind address, host:port; port 0 = OS-assigned;\n\
             --listen is shorthand)\n\
           comm.peers (multi-process mesh; not yet supported — rejected with a\n\
             clear message)\n\
           comm.round_timeout (per-receive deadline in seconds; a peer silent\n\
             past it is dropped by renormalizing its mixing row. 0 = off;\n\
             needs bus|tcp; --round-timeout is shorthand)\n\
           comm.compression (none|topk|int8), comm.topk_frac, comm.int8_block\n\
           trace.path (write per-phase span timeline as Chrome trace-event\n\
             JSON; --trace out.json is shorthand. Empty = off: every probe is\n\
             a no-op and the run is byte-for-byte the untraced one)\n\
           trace.capacity (per-worker span ring size, default 65536; oldest\n\
             spans evict past it, counted in spans_dropped)\n\
           cost.alpha / cost.theta / cost.compute (scalar or per-node array)\n\
           cost.straggler (\"idx:factor,...\"; --straggler is shorthand and accepts\n\
             a comma-separated list (--straggler 0:4,3:2) or repeats; duplicate\n\
             indices are rejected. Scales that node's compute + latency — see\n\
             costmodel::NodeCosts)"
    );
}

/// Flags that may appear bare (`--overlap`) or with an explicit boolean
/// (`--overlap false`).
const BOOL_FLAGS: &[&str] = &["overlap", "stealing", "surrogate", "pin"];

/// Parse `--flag value` pairs (boolean flags may omit the value).
fn parse_flags(args: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if BOOL_FLAGS.contains(&name) {
                match args.get(i + 1).map(|s| s.as_str()) {
                    Some(v @ ("true" | "false")) => {
                        out.push((name.to_string(), v.to_string()));
                        i += 2;
                    }
                    _ => {
                        out.push((name.to_string(), "true".to_string()));
                        i += 1;
                    }
                }
                continue;
            }
            let val = args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--{name} needs a value"))?;
            out.push((name.to_string(), val.clone()));
            i += 2;
        } else {
            bail!("unexpected argument '{a}'");
        }
    }
    Ok(out)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    let mut doc = Toml::default();
    // --config loads first, regardless of flag order, so --set/--threads
    // always override the file (a trailing --config must not discard them).
    for (name, val) in &flags {
        if name == "config" {
            doc = Toml::load(std::path::Path::new(val))?;
        }
    }
    // --straggler is repeatable; collect every spec before writing the one
    // cost.straggler key (a later flag must extend, not overwrite).
    let straggler_specs: Vec<&str> = flags
        .iter()
        .filter(|(k, _)| k == "straggler")
        .map(|(_, v)| v.as_str())
        .collect();
    if !straggler_specs.is_empty() {
        let joined = straggler_specs.join(",");
        gossip_pga::config::parse_stragglers(&joined)
            .with_context(|| format!("--straggler wants idx:factor, got '{joined}'"))?;
        doc.values.insert(
            "cost.straggler".into(),
            gossip_pga::config::Value::Str(joined),
        );
    }
    for (name, val) in &flags {
        match name.as_str() {
            "config" => {}
            "straggler" => {}
            "set" => {
                let (k, v) = val
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("--set wants key=value, got '{val}'"))?;
                let parsed = Toml::parse(&format!("{k} = {v}"))
                    .or_else(|_| Toml::parse(&format!("{k} = \"{v}\"")))?;
                doc.values.extend(parsed.values);
            }
            "threads" => {
                let parsed = Toml::parse(&format!("train.threads = {val}"))
                    .with_context(|| format!("--threads wants an integer, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "overlap" => {
                let parsed = Toml::parse(&format!("train.overlap = {val}"))
                    .with_context(|| format!("--overlap wants a bool, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "stealing" => {
                let parsed = Toml::parse(&format!("train.stealing = {val}"))
                    .with_context(|| format!("--stealing wants a bool, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "pin" => {
                let parsed = Toml::parse(&format!("train.pin = {val}"))
                    .with_context(|| format!("--pin wants a bool, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "pipeline-depth" => {
                let parsed = Toml::parse(&format!("train.pipeline_depth = {val}"))
                    .with_context(|| format!("--pipeline-depth wants an integer, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "backend" => {
                let parsed = Toml::parse(&format!("comm.backend = \"{val}\""))
                    .with_context(|| format!("--backend wants shared|bus|tcp, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "listen" => {
                let parsed = Toml::parse(&format!("comm.listen = \"{val}\""))
                    .with_context(|| format!("--listen wants host:port, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "peers" => {
                // Parsed so the config layer can reject it with the real
                // message (multi-process tcp is not yet supported).
                let parsed = Toml::parse(&format!("comm.peers = \"{val}\""))
                    .with_context(|| format!("--peers wants host:port[,...], got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "round-timeout" => {
                let parsed = Toml::parse(&format!("comm.round_timeout = {val}"))
                    .with_context(|| format!("--round-timeout wants seconds, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "regime" => {
                let parsed = Toml::parse(&format!("train.regime = \"{val}\""))
                    .with_context(|| format!("--regime wants bsp|overlap|async, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "max-staleness" => {
                let parsed = Toml::parse(&format!("train.max_staleness = {val}"))
                    .with_context(|| format!("--max-staleness wants an integer, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            "trace" => {
                let parsed = Toml::parse(&format!("trace.path = \"{val}\""))
                    .with_context(|| format!("--trace wants an output path, got '{val}'"))?;
                doc.values.extend(parsed.values);
            }
            other => bail!("unknown flag --{other}"),
        }
    }
    let cfg = ExperimentConfig::from_toml(&doc).context("building experiment config")?;
    // Trace preflight: fail on an unwritable path BEFORE artifacts load and
    // the run burns minutes (the real write happens after training).
    if !cfg.trace_path.is_empty() {
        let path = std::path::Path::new(&cfg.trace_path);
        std::fs::File::create(path)
            .with_context(|| format!("--trace: cannot write trace file '{}'", path.display()))?;
    }
    let topo = cfg.topology();
    println!(
        "# {} | {} nodes on {} (beta = {}) | H = {} | {} steps | {} thread(s){}{}{}{} | {} backend{}",
        cfg.algorithm.display(),
        cfg.nodes,
        cfg.topology,
        topo.beta_report(),
        cfg.period,
        cfg.steps,
        cfg.threads,
        if cfg.stealing { " (stealing)" } else { "" },
        if cfg.pin { " (pinned)" } else { "" },
        if cfg.pipeline_depth > 1 {
            format!(" | pipeline depth {}", cfg.pipeline_depth)
        } else {
            String::new()
        },
        match cfg.regime_kind().expect("validated") {
            gossip_pga::eventsim::Regime::Bsp => String::new(),
            gossip_pga::eventsim::Regime::Overlap => " | overlap".into(),
            gossip_pga::eventsim::Regime::Async =>
                format!(" | async (max staleness {})", cfg.max_staleness),
        },
        cfg.backend,
        if cfg.compression == "none" {
            String::new()
        } else {
            format!(" | {} compression", cfg.compression)
        }
    );
    for &(idx, factor) in &cfg.stragglers {
        println!("# straggler: node {idx} x{factor} (compute + latency)");
    }

    let rt = Arc::new(Runtime::load_default().context("loading artifacts (run `make artifacts`)")?);
    let (workload, init) = match cfg.model.as_str() {
        "logreg" => coordinator::logreg_workload(rt, cfg.nodes, cfg.samples_per_node, cfg.non_iid, cfg.seed)?,
        "mlp" => coordinator::mlp_workload(rt, cfg.nodes, cfg.samples_per_node, cfg.non_iid, cfg.seed)?,
        "transformer" => coordinator::lm_workload(rt, &cfg.model_tag, cfg.seed)?,
        other => bail!("unknown model '{other}'"),
    };
    let cost_dim = workload.flat_dim();
    // from_config resolves BOTH the base cost model and any [cost]/
    // --straggler per-node table from the same calibration; overriding
    // opts.cost after this point would silently leave node_costs on the
    // old base, so don't.
    let opts = TrainerOptions::from_config(&cfg, cost_dim);
    let mut trainer = coordinator::Trainer::new(workload, init, opts)?;

    if !cfg.trace_path.is_empty() {
        gossip_pga::obs::start(cfg.trace_capacity);
    }
    let t0 = std::time::Instant::now();
    let hist = trainer.run(cfg.steps, cfg.algorithm.name())?;
    let wall = t0.elapsed().as_secs_f64();
    // Counters BEFORE stop: spans_dropped reads the live thread ring.
    let counters = trainer.counters();
    if !cfg.trace_path.is_empty() {
        let data = gossip_pga::obs::stop_and_collect();
        let doc = gossip_pga::obs::chrome::export(&data, &counters);
        let path = std::path::Path::new(&cfg.trace_path);
        std::fs::write(path, doc.dump())
            .with_context(|| format!("writing trace file '{}'", path.display()))?;
        println!(
            "# trace: {} span(s) across {} thread(s) ({} dropped) written to {}",
            data.total_spans(),
            data.threads.len(),
            data.total_dropped(),
            path.display()
        );
    }

    for r in &hist.records {
        println!(
            "step {:>6}  loss {:.5}  consensus {:.3e}  lr {:.4}  sim_t {:.1}s",
            r.step, r.loss, r.consensus, r.lr, r.sim_seconds
        );
    }
    println!(
        "# done: final loss {:.5} | sim time {:.2} h | wall {:.1}s | final H {}",
        hist.final_loss(),
        hist.final_sim_hours(),
        wall,
        trainer.current_period()
    );
    let comm = trainer.comm_stats();
    println!(
        "{}",
        gossip_pga::metrics::traffic_line(trainer.backend_kind().name(), &comm, &counters)
    );
    // Heterogeneous cost tables always get the breakdown; so do runs where
    // structural asymmetry (star hubs, uneven bus chunks) opened real
    // slack or waits despite identical node costs.
    if !trainer.node_costs().is_homogeneous()
        || trainer.straggler_slack() > 0.0
        || trainer.barrier_wait_seconds() > 0.0
    {
        println!(
            "# virtual time: critical path {:.1}s | fastest node {:.1}s | slack {:.1}s | barrier wait {:.1}s",
            trainer.sim_seconds(),
            trainer.sim_seconds_min(),
            trainer.straggler_slack(),
            trainer.barrier_wait_seconds()
        );
    }
    if comm.fallback_rounds > 0 {
        println!(
            "# overlap fallback: {} gossip round(s) ran synchronously (compressed transmit has no async path)",
            comm.fallback_rounds
        );
    }
    if let Some(hist) = trainer.staleness_histogram() {
        let (stale_max, stale_mean) = trainer.staleness();
        let shown: Vec<String> =
            hist.iter().enumerate().map(|(s, c)| format!("{s}:{c}")).collect();
        println!(
            "# staleness: max {stale_max} | mean {stale_mean:.3} | histogram {{{}}}",
            shown.join(", ")
        );
        println!("# links: mean utilization {:.1}%", trainer.link_utilization() * 100.0);
    }
    if let Some(acc) = coordinator::mlp_eval_accuracy(&trainer)? {
        println!("# eval accuracy: {:.2}%", acc * 100.0);
    }
    if let Some(loss) = coordinator::lm_eval_loss(&trainer, 4, cfg.seed)? {
        println!("# eval LM loss: {loss:.4}");
    }
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    use gossip_pga::algorithms::AlgorithmKind;
    use gossip_pga::config::SweepConfig;
    use gossip_pga::costmodel::{CostModel, RegionMap};
    use gossip_pga::population::{run_sweep, ChurnScript, SweepSpec};

    let flags = parse_flags(args)?;
    let mut cfg = SweepConfig::default();
    let mut straggler_specs: Vec<&str> = Vec::new();
    for (name, val) in &flags {
        match name.as_str() {
            "virtual-n" => cfg.virtual_n = val.parse().context("--virtual-n wants an integer")?,
            "topology" => cfg.topology = val.clone(),
            "algo" => cfg.algorithm = AlgorithmKind::from_name(val)?,
            "period" => cfg.period = val.parse().context("--period wants an integer")?,
            "steps" => cfg.steps = val.parse().context("--steps wants an integer")?,
            "max-staleness" => {
                cfg.max_staleness = val.parse().context("--max-staleness wants an integer")?
            }
            "surrogate" => cfg.surrogate = val == "true",
            "dim" => cfg.dim = val.parse().context("--dim wants an integer")?,
            "seed" => cfg.seed = val.parse().context("--seed wants an integer")?,
            "cost-dim" => cfg.cost_dim = val.parse().context("--cost-dim wants an integer")?,
            "churn" => cfg.churn = val.clone(),
            "churn-pairs" => {
                cfg.churn_pairs = val.parse().context("--churn-pairs wants an integer")?
            }
            "churn-seed" => cfg.churn_seed = val.parse().context("--churn-seed wants an integer")?,
            "churn-horizon" => {
                cfg.churn_horizon = val.parse().context("--churn-horizon wants seconds")?
            }
            "regions" => cfg.regions = val.clone(),
            "straggler" => straggler_specs.push(val),
            "log-points" => cfg.log_points = val.parse().context("--log-points wants an integer")?,
            "report" => cfg.report = val.clone(),
            other => bail!("unknown flag --{other}"),
        }
    }
    if !straggler_specs.is_empty() {
        cfg.stragglers = gossip_pga::config::parse_stragglers(&straggler_specs.join(","))?;
    }
    cfg.validate().context("building sweep config")?;

    let topo = Topology::from_name(&cfg.topology, cfg.virtual_n)?;
    let mut churn = ChurnScript::parse(&cfg.churn).context("parsing --churn")?.events;
    if cfg.churn_pairs > 0 {
        let seeded =
            ChurnScript::seeded(cfg.churn_seed, &topo, cfg.churn_pairs, cfg.churn_horizon)?;
        churn.extend(seeded.events);
    }
    let regions = match cfg.region_spec()? {
        Some((k, mult)) => Some(RegionMap::tiers(cfg.virtual_n, k, 1.0, mult)?),
        None => None,
    };
    let spec = SweepSpec {
        topo,
        algo: cfg.algorithm,
        h: cfg.period,
        steps: cfg.steps,
        max_staleness: cfg.max_staleness,
        dim: if cfg.surrogate { 0 } else { cfg.dim },
        seed: cfg.seed,
        cost: CostModel::calibrated_resnet50(),
        cost_dim: cfg.cost_dim,
        stragglers: cfg.stragglers.clone(),
        churn,
        regions,
        log_points: cfg.log_points,
    };
    println!(
        "# sweep: {} virtual nodes on {} (beta = {}) | {} | H = {} | {} steps | {} payloads | {} churn event(s)",
        cfg.virtual_n,
        cfg.topology,
        spec.topo.beta_report(),
        cfg.algorithm.display(),
        cfg.period,
        cfg.steps,
        if spec.dim == 0 { "surrogate".to_string() } else { format!("dense d={}", spec.dim) },
        spec.churn.len(),
    );
    let t0 = std::time::Instant::now();
    let report = run_sweep(&spec)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["step", "time(s)", "consensus", "scalars", "msgs", "alive", "stale", "util"]);
    for p in &report.curve {
        t.rowv(vec![
            p.step.to_string(),
            format!("{:.1}", p.time),
            format!("{:.3e}", p.consensus),
            p.scalars.to_string(),
            p.msgs.to_string(),
            p.alive.to_string(),
            format!("{}/{:.2}", p.stale_max, p.stale_mean),
            format!("{:.2}", p.link_util),
        ]);
    }
    t.print();
    let (crashes, rejoins, link_events, missed) = report.churn_counts;
    println!(
        "# churn: {crashes} crash(es) | {rejoins} rejoin(s) | {link_events} link event(s) | {missed} missed barrier(s)"
    );
    println!(
        "# memory audit: {} directed links | peak {} pooled slots | peak {} dense scalars",
        report.num_links, report.peak_live_slots, report.peak_dense_scalars
    );
    match report.transient_step {
        Some(s) => println!("# transient: consensus contracted 100x by step {s}"),
        None => println!("# transient: consensus has not contracted 100x within the sweep"),
    }
    println!("# wall: {wall:.1}s");
    if !cfg.report.is_empty() {
        let path = std::path::Path::new(&cfg.report);
        report.write_json(path)?;
        println!("# report written to {}", path.display());
    }
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let path = match args {
        [p] if !p.starts_with("--") => std::path::Path::new(p),
        _ => bail!("usage: gossip-pga trace out.json (a file written by train --trace)"),
    };
    let doc = gossip_pga::obs::chrome::load(path)?;
    print!("{}", gossip_pga::obs::chrome::summarize(&doc)?);
    Ok(())
}

fn cmd_topo(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    let n: usize = flags
        .iter()
        .find(|(k, _)| k == "n")
        .map(|(_, v)| v.parse())
        .transpose()?
        .unwrap_or(32);
    let mut t = Table::new(&["topology", "beta", "1-beta", "C_beta(H=16)", "D_beta(H=16)", "regime"]);
    for name in ["ring", "grid", "star", "expo", "one-peer-expo", "full"] {
        let topo = Topology::from_name(name, n)?;
        // Size-gated: above BETA_DENSE_LIMIT the dense spectral path would
        // allocate an n x n matrix just for this report.
        match topo.beta_report().exact() {
            Some(beta) => t.rowv(vec![
                name.to_string(),
                format!("{beta:.5}"),
                format!("{:.2e}", 1.0 - beta),
                format!("{:.3}", spectral::c_beta(beta, 16)),
                format!("{:.3}", spectral::d_beta(beta, 16)),
                format!("{:?}", spectral::regime(beta, 16)),
            ]),
            None => t.rowv(vec![
                name.to_string(),
                "skipped".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                format!("n > {}", gossip_pga::topology::BETA_DENSE_LIMIT),
            ]),
        }
    }
    println!("n = {n}");
    t.print();
    Ok(())
}

fn cmd_check() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("artifacts dir: {}", rt.manifest.dir.display());
    let mut t = Table::new(&["artifact", "model", "kind", "flat_dim", "compiles"]);
    let names: Vec<String> = rt.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    for name in names {
        let a = rt.manifest.by_name(&name)?.clone();
        let ok = rt.executable(&name).map(|_| "yes").unwrap_or("NO");
        t.rowv(vec![a.name, a.model, a.kind, a.flat_dim.to_string(), ok.to_string()]);
    }
    t.print();
    Ok(())
}
