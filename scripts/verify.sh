#!/usr/bin/env bash
# One-shot verification gate for every PR, fail-fast ordered:
#   1. formatting: cargo fmt --check            (seconds — run it first)
#   2. lints: cargo clippy -D warnings          (this is also the
#      rust/src/exec/ gate — any new warning there fails the run)
#   3. tier-1: release build + full test suite (ROADMAP.md)
#   4. schedule-equivalence property suite at PROPTEST_CASES=16, swept over
#      GOSSIP_PGA_TEST_THREADS=1 and =4 (pooled == scoped == sequential;
#      work-stealing == static sharding; overlap == BSP at every k*H
#      boundary; bus backend == shared backend)
#   5. virtual-time straggler smoke at PROPTEST_CASES=16: per-node clocks
#      reproduce the scalar SimClock bit-exactly when homogeneous (both
#      backends), stragglers bend clocks but never parameter bits, and
#      checkpoint v4 resumes keep the per-node time axis
#   6. eventsim smoke at PROPTEST_CASES=16: the event-driven async regime —
#      strict mode (max_staleness = 0) equals barrier-billed clocks AND the
#      BSP trajectory bit-exactly on both backends, bounded-stale mixing
#      respects --max-staleness under multi-stragglers, checkpoint v5
#      resumes mid-flight payloads bit-exactly, and the event order is
#      pool-size-invariant (no AOT artifacts needed)
#   7. population smoke at PROPTEST_CASES=16 + GOSSIP_PGA_FAST: the virtual
#      population plane — full materialization reproduces the per-link
#      storage engine bit-exactly on both backends, the dense virtual plane
#      replays the materialized event schedule, seeded churn scripts replay
#      bit-exactly, sweeps are pure functions of their spec, and the
#      large-n smoke (GOSSIP_PGA_FAST trims the flagship 10^5 to 10^4)
#      passes the allocation audit (beta skipped, zero dense payloads)
#   8. comm-accounting smoke: the rewritten tab17 bench replays a schedule
#      on both CommPlane backends and asserts measured == predicted ==
#      analytic traffic, the straggler gate (gossip's critical path
#      degrades less than all-reduce's under a seeded 4x straggler), AND
#      the event-plane gate (async critical path below the neighborhood-
#      barrier bill under multi-stragglers; strict mode bit-equal); it
#      needs no AOT artifacts, so backend accounting cannot silently rot.
#   9. transport smoke at PROPTEST_CASES=16 + GOSSIP_PGA_FAST: the socket
#      plane — shared == bus == tcp bit-equality over real loopback
#      sockets (every test binds 127.0.0.1:0, OS-assigned ports, so no
#      hardcoded-port flakes), the round state machine's drop/rejoin/
#      checkpoint-v7 acceptance path, and the BENCH_7.json schema gate
#      (the bit-equality replay needs no AOT artifacts; the trainer-level
#      fault tests do)
#  10. hot-path smoke at PROPTEST_CASES=16: the kernel-equivalence property
#      suite (blocked/vectorized mix_row_src == the naive scalar reference,
#      bit for bit, across every row-shape arm and the MIX_BLOCK boundary)
#      and the pipelining suite (depth {1,2,4} chained async gossip ==
#      BSP at every k*H / eval / checkpoint drain on mixer, backend and
#      trainer layers, plus the BENCH_8.json schema gate; the kernel and
#      mixer/backend layers need no AOT artifacts)
#  11. overlap-on-the-wire smoke at PROPTEST_CASES=16, swept over
#      GOSSIP_PGA_TEST_THREADS=1 and =4: the message-passing backends'
#      async gossip — overlapped/pipelined bus and tcp == BSP at every
#      drained boundary with fallback_rounds == 0, stale epoch-tagged
#      frames discarded + counted + bit-harmless on both wires, the
#      checkpoint-restore stale-tally re-baseline, and the BENCH_9.json
#      schema gate (the backend replay layers need no AOT artifacts;
#      every socket test binds 127.0.0.1:0 under a watchdog)
#  12. observability smoke at PROPTEST_CASES=16: the trace plane — traced
#      replays bit-identical to untraced on shared/bus/tcp (sync and
#      pipelined), drop-oldest ring overflow tallied in spans_dropped,
#      chrome export round-trips dump -> parse -> validate with monotone
#      ts per tid, `trace` subcommand error surface, warn-once capture,
#      and the BENCH_10.json schema gate (the backend replay layers need
#      no AOT artifacts; the trainer-level test skips without them)
#
# Usage: scripts/verify.sh [--fast]
#   --fast   sets GOSSIP_PGA_FAST=1 so bench-derived tests run at reduced
#            scale (the tab17 smoke always runs in fast mode).
#
# Integration tests and benches need the AOT artifacts (`make artifacts`);
# unit tests and the tab17 smoke run without them.

set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--fast" ]]; then
  export GOSSIP_PGA_FAST=1
fi

echo "==> cargo fmt --check  (fail fast)"
cargo fmt --check

echo "==> cargo clippy -- -D warnings  (includes the rust/src/exec/ gate)"
cargo clippy --all-targets -- -D warnings

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

echo "==> schedule-equivalence properties (PROPTEST_CASES=16, threads=1)"
PROPTEST_CASES=16 GOSSIP_PGA_TEST_THREADS=1 cargo test -q --test properties

# threads=4 is also the in-test default, so tier-1 already covered these 16
# cases at 64; this run is kept as the explicit, cheap contract gate the
# issue asks for (and stays meaningful if the defaults ever change).
echo "==> schedule-equivalence properties (PROPTEST_CASES=16, threads=4)"
PROPTEST_CASES=16 GOSSIP_PGA_TEST_THREADS=4 cargo test -q --test properties

echo "==> virtual-time plane: homogeneous bit-exactness + straggler properties"
PROPTEST_CASES=16 cargo test -q --test virtual_time

echo "==> event plane: strict-mode anchor + staleness bound + v6 resume + determinism"
PROPTEST_CASES=16 cargo test -q --test eventsim

echo "==> population plane: materialization anchor + churn replay + large-n smoke (n = 10^4)"
PROPTEST_CASES=16 GOSSIP_PGA_FAST=1 cargo test -q --test population

echo "==> CommPlane accounting smoke incl. straggler + event-plane gates (tab17, fast mode)"
GOSSIP_PGA_FAST=1 cargo bench --bench tab17_comm_overhead

echo "==> transport plane: tcp bit-equality + round drop/rejoin/checkpoint-v7 (loopback, port 0)"
PROPTEST_CASES=16 GOSSIP_PGA_FAST=1 cargo test -q --test transport

echo "==> hot path: blocked-kernel bit-equivalence properties"
PROPTEST_CASES=16 cargo test -q --test mix_kernel

echo "==> hot path: depth-k gossip pipelining == BSP at every drained boundary"
PROPTEST_CASES=16 cargo test -q --test pipeline

echo "==> overlap on the wire: bus + tcp async gossip == BSP, zero fallbacks (threads=1)"
PROPTEST_CASES=16 GOSSIP_PGA_TEST_THREADS=1 cargo test -q --test overlap_wire

echo "==> overlap on the wire: bus + tcp async gossip == BSP, zero fallbacks (threads=4)"
PROPTEST_CASES=16 GOSSIP_PGA_TEST_THREADS=4 cargo test -q --test overlap_wire

echo "==> observability: traced == untraced bit-for-bit + chrome schema + warn-once"
PROPTEST_CASES=16 cargo test -q --test obs_trace

echo "==> verify OK"
