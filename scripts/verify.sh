#!/usr/bin/env bash
# One-shot verification gate for every PR:
#   1. tier-1: release build + full test suite (ROADMAP.md)
#   2. formatting: cargo fmt --check
#   3. lints: cargo clippy -D warnings
#
# Usage: scripts/verify.sh [--fast]
#   --fast   sets GOSSIP_PGA_FAST=1 so bench-derived tests run at 1/4 scale.
#
# Integration tests and benches need the AOT artifacts (`make artifacts`);
# unit tests run without them.

set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--fast" ]]; then
  export GOSSIP_PGA_FAST=1
fi

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> verify OK"
